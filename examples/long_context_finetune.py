"""End-to-end long-context fine-tuning on the paper's length distribution.

Trains a reduced mamba2 (SSD) model — the family where ChunkFlow's state is
O(1) — for a few hundred steps on synthetic long-tail data, demonstrating:
  * loss goes down (full substrate: data -> Alg1 -> Alg2 -> AdamW -> ckpt)
  * peak live activations stay at K chunks regardless of sequence length

    PYTHONPATH=src python examples/long_context_finetune.py [--steps 30]
"""
import argparse

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--arch", default="mamba2-130m")
ap.add_argument("--chunk-size", type=int, default=128)
ap.add_argument("--k", type=int, default=2)
args = ap.parse_args()

cfg = get_arch(args.arch).reduced()
tc = TrainConfig(chunk_size=args.chunk_size, k_chunks=args.k,
                 learning_rate=1e-3, total_steps=args.steps, warmup_steps=5)
params, opt, history = train(cfg, tc, batch_per_step=8, max_len=1024,
                             checkpoint_path="/tmp/chunkflow_ckpt.msgpack")

first = sum(h["loss"] for h in history[:5]) / 5
last = sum(h["loss"] for h in history[-5:]) / 5
print(f"mean loss first5 {first:.3f} -> last5 {last:.3f}")
assert last < first, "loss should decrease"
assert all(h["peak_residuals"] <= tc.k_chunks for h in history)
print("ok: loss decreased, activation bound held")
