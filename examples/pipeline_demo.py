"""State-aware pipeline parallelism demo (paper §4.3) on 4 fake devices.

Runs the shard_map 1F1B rotation executor over a chunk stream containing a
dependent group, checks the gradients against the single-device ChunkFlow
scheduler, and prints the schedule-level bubble analysis for the same stream.

    PYTHONPATH=src python examples/pipeline_demo.py
(This file self-re-executes with XLA_FLAGS for 4 host devices.)
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import chunked_step, chunking
from repro.core.schedule_sim import chunks_to_microbatches, simulate_1f1b
from repro.distributed import pipeline
from repro.models import api

cfg = ModelConfig(name="demo", family="dense", num_layers=4, d_model=32,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=61, dtype="float32", rope_theta=10_000.0)
S, C = 4, 16
mesh = jax.make_mesh((S,), ("pipe",))
params = api.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.RandomState(0)

lengths = {0: 3 * C, 1: 9, 2: 5, 3: 12, 4: 7}
seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
        for i, l in lengths.items()}
chunks = chunking.construct_chunks(lengths, C)
groups, standalone = chunking.group_chunks(chunks)
ordered = groups[0] + standalone
mats = [chunking.materialize_chunk(c, seqs) for c in ordered]

# (M, B=1, T) arrays per key
batch = {k: jnp.asarray(np.stack([m[k][0] for m in mats]))[:, None]
         for k in mats[0]}
total = float(sum(m["loss_mask"].sum() for m in mats))
batch["dep_flags"] = jnp.asarray(
    [1 if c.dependent else 0 for c in ordered], jnp.int32)
batch["loss_scale"] = jnp.float32(1.0 / total)

step = pipeline.make_pipeline_step(cfg, mesh, S, C)
loss, grads = step(params, batch)
print(f"pipeline loss over {len(ordered)} chunks on {S} stages: "
      f"{float(loss):.4f}")

gb = [[{k: jnp.asarray(v) for k, v in
        chunking.materialize_chunk(c, seqs).items()} for c in groups[0]]]
sb = [{k: jnp.asarray(v) for k, v in
       chunking.materialize_chunk(c, seqs).items()} for c in standalone]
ref_loss, ref_grads, _ = chunked_step.run_batch(cfg, params, gb, sb, k=1)
np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
print("matches single-device ChunkFlow scheduler ✓")

mbs = chunks_to_microbatches(ordered, k=1)
r = simulate_1f1b(mbs, S, state_aware=True)
print(f"schedule analysis: bubble ratio {r.bubble_ratio:.1%}, "
      f"makespan {r.makespan:.0f} units, recompute {r.recompute_time:.0f}")

# ---- the trainable path: 2D (data x pipe) K-retention executor ------------
from repro.core.schedule_sim import simulate_rotation
from repro.launch import mesh as mesh_lib

mesh2d = mesh_lib.make_train_mesh(2, 2)
for K in (1, 3):
    loss2d, grads2d, st = chunked_step.run_batch(cfg, params, gb, sb, k=K,
                                                 mesh=mesh2d)
    np.testing.assert_allclose(float(loss2d), float(ref_loss), rtol=1e-5)
    sim = simulate_rotation(st.wave_sizes, 2, K)
    assert abs(st.bubble_ratio - sim.bubble_ratio) < 1e-12
    print(f"2D (data=2 x pipe=2) K={K}: loss matches ✓, "
          f"recompute {st.recompute_calls} chunks, "
          f"bubble {st.bubble_ratio:.1%} (== simulator), "
          f"resident chunk-states {st.max_live_residuals}")
print("ok")
