"""Quickstart: ChunkFlow in ~40 lines.

Build a long-tail batch, reorganize it with Algorithm 1, run Algorithm 2's
state-aware schedule, and take an optimizer step — on a reduced Qwen-family
config that runs on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core import chunked_step, chunking
from repro.models import api
from repro.optim import adamw

cfg = get_arch("qwen2.5-14b").reduced()      # 2 layers, d=256 — CPU friendly
CHUNK_SIZE, K = 64, 1

# --- a long-tail batch: one long sequence + several short ones -------------
rng = np.random.RandomState(0)
lengths = {0: 200, 1: 30, 2: 17, 3: 50, 4: 9}
seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
        for i, l in lengths.items()}

# --- Algorithm 1: chunk construction ----------------------------------------
chunks = chunking.construct_chunks(lengths, CHUNK_SIZE)
groups, standalone = chunking.group_chunks(chunks)
print(f"{len(chunks)} chunks: {len(groups)} dependent group(s) "
      f"({[len(g) for g in groups.values()]} chunks), "
      f"{len(standalone)} packed standalone")

# --- Algorithm 2: state-aware scheduling + gradient accumulation ------------
params = api.init_params(cfg, jax.random.PRNGKey(0), max_seq=256)
opt = adamw.adamw_init(params)

to_dev = lambda c: {k: jax.numpy.asarray(v) for k, v in
                    chunking.materialize_chunk(c, seqs).items()}
gb = [[to_dev(c) for c in g] for g in groups.values()]
sb = [to_dev(c) for c in standalone]

for step in range(3):
    loss, grads, stats = chunked_step.run_batch(cfg, params, gb, sb, k=K)
    params, opt, gnorm = jax.jit(
        lambda p, g, o: adamw.adamw_update(p, g, o, lr=1e-3))(params, grads, opt)
    print(f"step {step}: loss {float(loss):.4f}  gnorm {float(gnorm):.2f}  "
          f"peak live activations {stats.max_live_residuals} chunk(s) "
          f"(K={K}), {stats.recompute_calls} recomputed forwards")
print("ok")
