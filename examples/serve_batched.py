"""Batched serving: chunked prefill + KV-cache decode on a reduced gemma2
(sliding-window + softcap variant exercises the decode masks).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.launch.serve import generate
from repro.models import api

cfg = get_arch("gemma2-2b").reduced()
params = api.init_params(cfg, jax.random.PRNGKey(0), max_seq=256)
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 1,
                             cfg.vocab_size)

t0 = time.time()
toks = generate(cfg, params, prompts, gen_len=16, chunk_size=32)
dt = time.time() - t0
print(f"generated {toks.shape[0]}x{toks.shape[1]} tokens in {dt:.1f}s")
assert toks.shape == (4, 16)
assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab_size).all()
print("ok")
