"""Continuous-batching serving on a reduced gemma2 (sliding-window + softcap
variant exercises the decode masks): requests stream through the engine's
paged KV cache and the greedy tokens match the static-batch reference
token-for-token.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.launch.serve import generate, serve_engine
from repro.models import api

cfg = get_arch("gemma2-2b").reduced()
params = api.init_params(cfg, jax.random.PRNGKey(0), max_seq=256)
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 1,
                             cfg.vocab_size)

t0 = time.time()
toks, engine = serve_engine(cfg, params, prompts, gen_len=16, chunk_size=32)
dt = time.time() - t0
print(f"engine generated {toks.shape[0]}x{toks.shape[1]} tokens in {dt:.1f}s")
print(engine.summary())
assert toks.shape == (4, 16)
assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab_size).all()

ref = generate(cfg, params, prompts, gen_len=16, chunk_size=32)
assert (np.asarray(toks) == np.asarray(ref)).all(), \
    "engine output diverged from the static-batch reference"
print("ok — engine matches static-batch reference")
