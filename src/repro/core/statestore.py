"""StateStore — the per-family chunk-state plumbing for Algorithm 2.

A *prefix* is the float-only state a chunk consumes from earlier chunks of its
group (K/V tensors, SSD states, whisper encoder output). Integer position /
segment arrays ride in the chunk batch instead, so `jax.vjp` only ever sees
differentiable state.

Static shapes: prefixes are allocated at a *capacity* bucketed to the next
power of two of the group's chunk count (`prefix_capacity`), and each chunk
writes its own K/V at offset ``i * C`` with `write_own`. Unused capacity
slots keep seg=0, so every attention backend masks them out exactly — and
every chunk of every group in the same bucket presents the executor's jitted
chunk fn with ONE shape, instead of a fresh shape (and a fresh XLA compile)
per chunk index. A standalone chunk is just capacity 0.

Operations:
  prefix_capacity(n_chunks, C)              bucketed KV capacity (pow2 * C)
  alloc_prefix(cfg, B, capacity)            capacity-padded zero prefix
  write_own(cfg, prefix, own, offset)       -> prefix with own K/V at offset
  assemble(cfg, prefix, batch)              -> api.forward state (adds pos/seg)
  slice_own(cfg, new_state, P)              -> this chunk's own contribution
  split_prefix_cot(cfg, cot, i, C)          -> {j: own-shaped cotangent}
      routes the KV gradients (paper §4.2 backward dependency) back to the
      chunks that produced each state slice; capacity-padded cotangent slots
      beyond i*C are zero (masked reads) and are simply dropped.
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dp_balance import prefix_capacity  # noqa: F401  (re-export)
from repro.models import api


def _attn_like(cfg: ModelConfig) -> bool:
    return cfg.family in ("dense", "moe", "vlm")


# ------------------------------------------------------- page geometry ------
# The serving path (serving/kv_pages.py, models/decode.decode_step_paged,
# kernels/decode_attention.paged_decode_attention) stores K/V in fixed-size
# *pages* instead of one dense (B, max_seq) cache. These pure-int helpers are
# the single source of truth for the page/chunk geometry the scheduler, the
# allocator and the kernels all have to agree on: token at absolute position
# ``pos`` of a request lives in the request's page-table entry ``pos // P``
# at in-page offset ``pos % P``.

def round_up(n: int, multiple: int) -> int:
    """Round ``n`` up to a multiple (chunk padding, pool sizing)."""
    assert multiple > 0
    return -(-n // multiple) * multiple


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages required to hold ``n_tokens`` KV slots (ceil division)."""
    assert page_size > 0
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // page_size)


def page_slot(pos: int, page_size: int):
    """-> (page_table_index, in_page_offset) of absolute KV slot ``pos``.
    Works on Python ints and on traced int32 arrays alike."""
    return pos // page_size, pos % page_size


def alloc_prefix(cfg: ModelConfig, batch: int, capacity: int, dtype=None):
    """Zero-filled prefix at ``capacity`` KV slots (seg=0 => fully masked)."""
    st = api.empty_state(cfg, batch, dtype, capacity=capacity)
    if _attn_like(cfg):
        return {"k": st["k"], "v": st["v"]}
    if cfg.family == "ssm":
        return st
    if cfg.family == "hybrid":
        return {"attn": {"k": st["attn"]["k"], "v": st["attn"]["v"]},
                "mamba": st["mamba"]}
    if cfg.family == "audio":
        return {"k": st["k"], "v": st["v"], "enc_out": None}
    raise ValueError(cfg.family)


def prefix_len(cfg: ModelConfig, prefix) -> int:
    """Static KV length (the capacity, for capacity-padded prefixes)."""
    if cfg.family == "ssm":
        return 0   # recurrent state has no length
    if cfg.family == "hybrid":
        return prefix["attn"]["k"].shape[2]
    return prefix["k"].shape[2]


def assemble(cfg: ModelConfig, prefix, batch):
    """Build the api.forward state from a float prefix + int pos/seg arrays."""
    p_pos = batch.get("prefix_pos")
    p_seg = batch.get("prefix_seg")
    if _attn_like(cfg) or cfg.family == "audio":
        st = {"k": prefix["k"], "v": prefix["v"], "pos": p_pos, "seg": p_seg}
        if cfg.family == "audio":
            st["enc_out"] = prefix.get("enc_out")
        return st
    if cfg.family == "ssm":
        return prefix
    if cfg.family == "hybrid":
        return {"attn": {"k": prefix["attn"]["k"], "v": prefix["attn"]["v"],
                         "pos": p_pos, "seg": p_seg},
                "mamba": prefix["mamba"]}
    raise ValueError(cfg.family)


def slice_own(cfg: ModelConfig, new_state, P: int):
    """Slice this chunk's own contribution out of forward()'s concatenated
    state. Returning only the slice keeps the vjp cotangent routing correct:
    prefix gradients flow through the attention *reads*, not the concat."""
    if _attn_like(cfg):
        return {"k": new_state["k"][:, :, P:], "v": new_state["v"][:, :, P:]}
    if cfg.family == "ssm":
        return new_state
    if cfg.family == "hybrid":
        return {"attn": {"k": new_state["attn"]["k"][:, :, P:],
                         "v": new_state["attn"]["v"][:, :, P:]},
                "mamba": new_state["mamba"]}
    if cfg.family == "audio":
        return {"k": new_state["k"][:, :, P:], "v": new_state["v"][:, :, P:],
                "enc_out": new_state["enc_out"]}
    raise ValueError(cfg.family)


def _write(buf, own, offset):
    return jax.lax.dynamic_update_slice_in_dim(
        buf, own.astype(buf.dtype), offset, axis=2)


def write_own(cfg: ModelConfig, prefix, own, offset: int):
    """Next chunk's prefix: write ``own`` K/V into the capacity buffer at KV
    slot ``offset`` (recurrent leaves are replaced wholesale). Functional —
    returns a new prefix tree."""
    if _attn_like(cfg):
        return {"k": _write(prefix["k"], own["k"], offset),
                "v": _write(prefix["v"], own["v"], offset)}
    if cfg.family == "ssm":
        return own
    if cfg.family == "hybrid":
        return {"attn": {"k": _write(prefix["attn"]["k"], own["attn"]["k"],
                                     offset),
                         "v": _write(prefix["attn"]["v"], own["attn"]["v"],
                                     offset)},
                "mamba": own["mamba"]}
    if cfg.family == "audio":
        return {"k": _write(prefix["k"], own["k"], offset),
                "v": _write(prefix["v"], own["v"], offset),
                "enc_out": own["enc_out"]}
    raise ValueError(cfg.family)


def _zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


def split_prefix_cot(cfg: ModelConfig, cot, i: int, chunk_size: int):
    """cot = gradient w.r.t. chunk i's *prefix input* (capacity-length for
    K/V — slots at or beyond i*C carry exact zeros since the chunk's reads
    were masked; the previous chunk's output for recurrent leaves). Returns
    {j: own-shaped cotangent contribution} for j < i."""
    if i == 0:
        return {}
    out = {}

    def kv_slice(kv, j):
        s = slice(j * chunk_size, (j + 1) * chunk_size)
        return {"k": kv["k"][:, :, s], "v": kv["v"][:, :, s]}

    for j in range(i):
        if _attn_like(cfg):
            out[j] = kv_slice(cot, j)
        elif cfg.family == "ssm":
            if j == i - 1:
                out[j] = cot
        elif cfg.family == "hybrid":
            c = {"attn": kv_slice(cot["attn"], j),
                 "mamba": (cot["mamba"] if j == i - 1
                           else _zeros_like(cot["mamba"]))}
            out[j] = c
        elif cfg.family == "audio":
            c = kv_slice(cot, j)
            if cot.get("enc_out") is not None:
                c["enc_out"] = (cot["enc_out"] if j == i - 1
                                else jnp.zeros_like(cot["enc_out"]))
            else:
                c["enc_out"] = None
            out[j] = c
    return out


# ------------------------------------------------------ host offload --------
@functools.lru_cache(maxsize=1)
def _pinned_host_sharding():
    """SingleDeviceSharding(memory_kind="pinned_host") when the backend
    exposes host memory spaces (TPU / recent GPU jaxlibs); None on backends
    without them (CPU) — the store then mirrors via plain numpy host
    arrays, which is semantically identical (only the DMA path differs)."""
    try:
        dev = jax.devices()[0]
        sh = jax.sharding.SingleDeviceSharding(dev, memory_kind="pinned_host")
        jax.device_put(jnp.zeros((1,), jnp.float32), sh).block_until_ready()
        return sh
    except Exception:
        return None


def _to_host(tree):
    """Mirror a device tree into (pinned, when available) host memory."""
    sh = _pinned_host_sharding()
    if sh is not None:
        return jax.tree.map(lambda x: jax.device_put(x, sh), tree)
    return jax.tree.map(np.asarray, tree)


def _tree_bytes(tree) -> int:
    return sum(int(x.size) * int(jnp.dtype(x.dtype).itemsize)
               for x in jax.tree.leaves(tree))


@dataclasses.dataclass
class PrefixStoreStats:
    """Residency accounting the executors surface in SchedulerStats."""
    device_bytes_peak: int = 0   # peak store-held device bytes (vjp-captured
    #                              residuals are accounted by max_live_residuals)
    host_bytes: int = 0          # peak host-mirrored bucket bytes
    prefetches: int = 0          # host->device bucket transfers issued
    offloaded: bool = False


class PrefixStore:
    """Versioned prefix buffer for Algorithm 2, with optional host offload.

    The executor writes version i+1 = `write_own(version_i, own_i, i*C)`
    after chunk i's forward and reads version i at chunk i's F and F2
    events. ``offload=False`` keeps every version on device (bit-compatible
    with the executor's original rolling list — version i stays alive until
    the group ends). ``offload=True`` bounds the device store:

      * only the LATEST version stays device-resident during the ascending
        forward sweep (retained chunks' vjp closures capture their own input
        version independently, so dropping older store references frees
        exactly the versions nothing will read again);
      * each newly written C-slot bucket ``own_i`` is mirrored to (pinned,
        when the backend has it) host memory;
      * `drop_device()` (first backward event — no more ascending reads)
        releases the rolling version too;
      * F2 re-reads are served by ONE reassembled buffer streamed back from
        the host buckets on the planner's access schedule
        (`planner.prefix_access_order`), transfers issued
        ``prefetch_depth`` buckets ahead (JAX async dispatch — the same
        double-buffering idiom as `data.prefetch.Prefetcher`) so they hide
        under the retained chunks' backward compute. Exactness: chunk i's
        prefix_seg metadata zeroes every slot at or beyond i*C, so a buffer
        holding MORE buckets than chunk i ever wrote reads identically to
        its original version — forward and cotangent alike
        (`split_prefix_cot` routes only j < i).

    Offload applies to K/V-bucketed families (dense/moe/vlm); recurrent
    leaves have no capacity buckets, so other families silently run
    un-offloaded.
    """

    def __init__(self, cfg: ModelConfig, init_prefix, n_chunks: int,
                 chunk_size: int, k: int, *, offload: bool = False,
                 prefetch_depth: int = 2, schedule=None):
        self.cfg = cfg
        self.n = n_chunks
        self.C = chunk_size
        self.k = max(1, k)
        self.offload = bool(offload) and _attn_like(cfg)
        self.prefetch_depth = max(1, int(prefetch_depth))
        self.schedule = list(schedule) if schedule is not None else None
        self._versions = {0: init_prefix}
        self._latest = 0
        self._host = {}            # bucket j -> host mirror of own_j
        self._reassembled = None
        self._spec = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), init_prefix)
        self.stats = PrefixStoreStats(offloaded=self.offload)
        self._note_device()

    def _note_device(self):
        held = [v for v in self._versions.values()]
        if self._reassembled is not None:
            held.append(self._reassembled)
        bytes_now = sum(_tree_bytes(v) for v in held)
        self.stats.device_bytes_peak = max(self.stats.device_bytes_peak,
                                           bytes_now)

    def put(self, version: int, prefix, own):
        """Record ``prefix`` as version ``version`` (chunk version-1's own
        bucket ``own`` written at offset (version-1)*C)."""
        if self.offload:
            self._host[version - 1] = _to_host(own)
            self.stats.host_bytes = max(
                self.stats.host_bytes,
                sum(_tree_bytes(b) for b in self._host.values()))
            self._versions = {version: prefix}
        else:
            self._versions[version] = prefix
        self._latest = version
        self._note_device()

    def get(self, i: int):
        """Prefix for chunk i's forward. F events read the live version;
        offloaded F2 re-reads get the reassembled buffer (exact by the
        seg-mask argument above)."""
        if i in self._versions:
            return self._versions[i]
        if not self.offload:
            raise KeyError(i)
        return self._reassemble()

    def drop_device(self):
        """Release the rolling device version (first backward event: the
        ascending sweep is over; retained vjp closures own what they need)."""
        if self.offload:
            self._versions = {}

    def _needed_buckets(self):
        """Buckets the F2 phase reads: the highest re-forwarded chunk is
        keep_from-1, which reads buckets j <= keep_from-2; lower F2 chunks
        read strict subsets (and mask the rest exactly)."""
        if self.schedule is not None and len(self.schedule) > self.n:
            f2 = self.schedule[self.n:]
            hi = max(f2) if f2 else 0
        else:
            hi = max(self.n - self.k, 0) - 1
        return [j for j in sorted(self._host) if j < hi]

    def _reassemble(self):
        if self._reassembled is not None:
            return self._reassembled
        leaves = jax.tree.leaves(self._spec)
        B = leaves[0].shape[1] if leaves[0].ndim > 3 else leaves[0].shape[0]
        cap = prefix_len(self.cfg, self._spec)
        buf = alloc_prefix(self.cfg, B, cap, leaves[0].dtype)
        queue = collections.deque()
        todo = self._needed_buckets()
        idx = 0
        while queue or idx < len(todo):
            # keep `prefetch_depth` host->device transfers in flight ahead
            # of the bucket being written (async dispatch overlaps them
            # with the writes and with the retained backward compute)
            while idx < len(todo) and len(queue) < self.prefetch_depth:
                j = todo[idx]
                idx += 1
                queue.append((j, jax.tree.map(jnp.asarray, self._host[j])))
                self.stats.prefetches += 1
            j, dev = queue.popleft()
            buf = write_own(self.cfg, buf, dev, j * self.C)
        self._reassembled = buf
        self._note_device()
        return buf


def tree_add(a, b):
    if a is None:
        return b
    if b is None:
        return a
    return jax.tree.map(
        lambda x, y: x + y if (x is not None and y is not None) else (x or y),
        a, b, is_leaf=lambda x: x is None)
