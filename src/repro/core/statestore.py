"""StateStore — the per-family chunk-state plumbing for Algorithm 2.

A *prefix* is the float-only state a chunk consumes from earlier chunks of its
group (K/V tensors, SSD states, whisper encoder output). Integer position /
segment arrays ride in the chunk batch instead, so `jax.vjp` only ever sees
differentiable state.

Static shapes: prefixes are allocated at a *capacity* bucketed to the next
power of two of the group's chunk count (`prefix_capacity`), and each chunk
writes its own K/V at offset ``i * C`` with `write_own`. Unused capacity
slots keep seg=0, so every attention backend masks them out exactly — and
every chunk of every group in the same bucket presents the executor's jitted
chunk fn with ONE shape, instead of a fresh shape (and a fresh XLA compile)
per chunk index. A standalone chunk is just capacity 0.

Operations:
  prefix_capacity(n_chunks, C)              bucketed KV capacity (pow2 * C)
  alloc_prefix(cfg, B, capacity)            capacity-padded zero prefix
  write_own(cfg, prefix, own, offset)       -> prefix with own K/V at offset
  assemble(cfg, prefix, batch)              -> api.forward state (adds pos/seg)
  slice_own(cfg, new_state, P)              -> this chunk's own contribution
  split_prefix_cot(cfg, cot, i, C)          -> {j: own-shaped cotangent}
      routes the KV gradients (paper §4.2 backward dependency) back to the
      chunks that produced each state slice; capacity-padded cotangent slots
      beyond i*C are zero (masked reads) and are simply dropped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dp_balance import prefix_capacity  # noqa: F401  (re-export)
from repro.models import api


def _attn_like(cfg: ModelConfig) -> bool:
    return cfg.family in ("dense", "moe", "vlm")


# ------------------------------------------------------- page geometry ------
# The serving path (serving/kv_pages.py, models/decode.decode_step_paged,
# kernels/decode_attention.paged_decode_attention) stores K/V in fixed-size
# *pages* instead of one dense (B, max_seq) cache. These pure-int helpers are
# the single source of truth for the page/chunk geometry the scheduler, the
# allocator and the kernels all have to agree on: token at absolute position
# ``pos`` of a request lives in the request's page-table entry ``pos // P``
# at in-page offset ``pos % P``.

def round_up(n: int, multiple: int) -> int:
    """Round ``n`` up to a multiple (chunk padding, pool sizing)."""
    assert multiple > 0
    return -(-n // multiple) * multiple


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages required to hold ``n_tokens`` KV slots (ceil division)."""
    assert page_size > 0
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // page_size)


def page_slot(pos: int, page_size: int):
    """-> (page_table_index, in_page_offset) of absolute KV slot ``pos``.
    Works on Python ints and on traced int32 arrays alike."""
    return pos // page_size, pos % page_size


def alloc_prefix(cfg: ModelConfig, batch: int, capacity: int, dtype=None):
    """Zero-filled prefix at ``capacity`` KV slots (seg=0 => fully masked)."""
    st = api.empty_state(cfg, batch, dtype, capacity=capacity)
    if _attn_like(cfg):
        return {"k": st["k"], "v": st["v"]}
    if cfg.family == "ssm":
        return st
    if cfg.family == "hybrid":
        return {"attn": {"k": st["attn"]["k"], "v": st["attn"]["v"]},
                "mamba": st["mamba"]}
    if cfg.family == "audio":
        return {"k": st["k"], "v": st["v"], "enc_out": None}
    raise ValueError(cfg.family)


def prefix_len(cfg: ModelConfig, prefix) -> int:
    """Static KV length (the capacity, for capacity-padded prefixes)."""
    if cfg.family == "ssm":
        return 0   # recurrent state has no length
    if cfg.family == "hybrid":
        return prefix["attn"]["k"].shape[2]
    return prefix["k"].shape[2]


def assemble(cfg: ModelConfig, prefix, batch):
    """Build the api.forward state from a float prefix + int pos/seg arrays."""
    p_pos = batch.get("prefix_pos")
    p_seg = batch.get("prefix_seg")
    if _attn_like(cfg) or cfg.family == "audio":
        st = {"k": prefix["k"], "v": prefix["v"], "pos": p_pos, "seg": p_seg}
        if cfg.family == "audio":
            st["enc_out"] = prefix.get("enc_out")
        return st
    if cfg.family == "ssm":
        return prefix
    if cfg.family == "hybrid":
        return {"attn": {"k": prefix["attn"]["k"], "v": prefix["attn"]["v"],
                         "pos": p_pos, "seg": p_seg},
                "mamba": prefix["mamba"]}
    raise ValueError(cfg.family)


def slice_own(cfg: ModelConfig, new_state, P: int):
    """Slice this chunk's own contribution out of forward()'s concatenated
    state. Returning only the slice keeps the vjp cotangent routing correct:
    prefix gradients flow through the attention *reads*, not the concat."""
    if _attn_like(cfg):
        return {"k": new_state["k"][:, :, P:], "v": new_state["v"][:, :, P:]}
    if cfg.family == "ssm":
        return new_state
    if cfg.family == "hybrid":
        return {"attn": {"k": new_state["attn"]["k"][:, :, P:],
                         "v": new_state["attn"]["v"][:, :, P:]},
                "mamba": new_state["mamba"]}
    if cfg.family == "audio":
        return {"k": new_state["k"][:, :, P:], "v": new_state["v"][:, :, P:],
                "enc_out": new_state["enc_out"]}
    raise ValueError(cfg.family)


def _write(buf, own, offset):
    return jax.lax.dynamic_update_slice_in_dim(
        buf, own.astype(buf.dtype), offset, axis=2)


def write_own(cfg: ModelConfig, prefix, own, offset: int):
    """Next chunk's prefix: write ``own`` K/V into the capacity buffer at KV
    slot ``offset`` (recurrent leaves are replaced wholesale). Functional —
    returns a new prefix tree."""
    if _attn_like(cfg):
        return {"k": _write(prefix["k"], own["k"], offset),
                "v": _write(prefix["v"], own["v"], offset)}
    if cfg.family == "ssm":
        return own
    if cfg.family == "hybrid":
        return {"attn": {"k": _write(prefix["attn"]["k"], own["attn"]["k"],
                                     offset),
                         "v": _write(prefix["attn"]["v"], own["attn"]["v"],
                                     offset)},
                "mamba": own["mamba"]}
    if cfg.family == "audio":
        return {"k": _write(prefix["k"], own["k"], offset),
                "v": _write(prefix["v"], own["v"], offset),
                "enc_out": own["enc_out"]}
    raise ValueError(cfg.family)


def _zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


def split_prefix_cot(cfg: ModelConfig, cot, i: int, chunk_size: int):
    """cot = gradient w.r.t. chunk i's *prefix input* (capacity-length for
    K/V — slots at or beyond i*C carry exact zeros since the chunk's reads
    were masked; the previous chunk's output for recurrent leaves). Returns
    {j: own-shaped cotangent contribution} for j < i."""
    if i == 0:
        return {}
    out = {}

    def kv_slice(kv, j):
        s = slice(j * chunk_size, (j + 1) * chunk_size)
        return {"k": kv["k"][:, :, s], "v": kv["v"][:, :, s]}

    for j in range(i):
        if _attn_like(cfg):
            out[j] = kv_slice(cot, j)
        elif cfg.family == "ssm":
            if j == i - 1:
                out[j] = cot
        elif cfg.family == "hybrid":
            c = {"attn": kv_slice(cot["attn"], j),
                 "mamba": (cot["mamba"] if j == i - 1
                           else _zeros_like(cot["mamba"]))}
            out[j] = c
        elif cfg.family == "audio":
            c = kv_slice(cot, j)
            if cot.get("enc_out") is not None:
                c["enc_out"] = (cot["enc_out"] if j == i - 1
                                else jnp.zeros_like(cot["enc_out"]))
            else:
                c["enc_out"] = None
            out[j] = c
    return out


def tree_add(a, b):
    if a is None:
        return b
    if b is None:
        return a
    return jax.tree.map(
        lambda x, y: x + y if (x is not None and y is not None) else (x or y),
        a, b, is_leaf=lambda x: x is None)
