"""Algorithm 2 — state-aware chunk scheduling, executed with jax.vjp.

The scheduler is split into a *pure schedule generator* (`alg2_schedule`,
shared with the pipeline simulator and unit-tested against the paper) and an
*executor* that walks the schedule holding at most K chunks' vjp residuals
alive — that is the paper's "peak memory = K * ChunkSize" mechanism, realised
here as: at most K live `jax.vjp` closures (XLA residual buffers), with the
first N-K chunks forwarded twice (the second time producing residuals right
before their backward).

Gradients are accumulated across chunks (and across the K/V state reads —
`statestore.split_prefix_cot` routes each chunk's prefix gradient back to the
producing chunks), which makes the whole thing mathematically equivalent to a
full-sequence step; tests/test_chunked_equivalence.py asserts this to ~1e-5.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dp_balance
from repro.core import statestore as ss
from repro.distributed import sharding
from repro.models import api


# ------------------------------------------------------------ schedule ------
def alg2_schedule(n_chunks: int, k: int):
    """Events: ("F", i, keep_residuals), ("B", i), ("F2", i).
    Forward ascending; keep residuals only for the last K; backward descending;
    first N-K chunks re-forwarded immediately before their backward."""
    n, k = n_chunks, max(1, k)
    keep_from = max(n - k, 0)
    ev = [("F", i, i >= keep_from) for i in range(n)]
    ev += [("B", i) for i in reversed(range(keep_from, n))]
    for i in reversed(range(keep_from)):
        ev += [("F2", i), ("B", i)]
    return ev


@dataclasses.dataclass
class SchedulerStats:
    forward_calls: int = 0
    recompute_calls: int = 0
    backward_calls: int = 0
    max_live_residuals: int = 0
    ring_steps: int = 0       # context-parallel ppermute hops (0 without CP)


# ---------------------------------------------------------- chunk fn --------
def token_nll_sum(logits, labels, loss_mask):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * loss_mask)


# Trace-time log of the jitted chunk fn: one entry per *Python retrace*
# (== per fresh XLA compile), recording the (prefix_capacity, chunk_len)
# shape signature. With the static-shape StateStore this stays O(#buckets)
# for a mixed batch; tests/test_compile_count.py pins that.
TRACE_EVENTS: list = []


def reset_trace_log():
    TRACE_EVENTS.clear()
    _jitted_chunk_fn.cache_clear()


@functools.lru_cache(maxsize=None)
def _jitted_chunk_fn(cfg: ModelConfig, blockwise_threshold: int):
    def f(params, prefix, batch):
        P = ss.prefix_len(cfg, prefix)
        TRACE_EVENTS.append((cfg.name, P, batch["tokens"].shape[1]))
        state = ss.assemble(cfg, prefix, batch)
        logits, new_state, aux = api.forward(
            cfg, params, batch, state, blockwise_threshold=blockwise_threshold)
        own = ss.slice_own(cfg, new_state, P)
        loss = token_nll_sum(logits, batch["labels"], batch["loss_mask"])
        loss = loss + aux["moe_aux"]
        return loss, own
    return jax.jit(f)


def chunk_batch_with_prefix(chunk_batch: dict, prefix_meta):
    """Attach prefix pos/seg (int arrays, non-differentiable) to the batch."""
    b = dict(chunk_batch)
    b["prefix_pos"], b["prefix_seg"] = prefix_meta
    return b


def _prefix_meta_init(B, capacity: int):
    return (jnp.zeros((B, capacity), jnp.int32),
            jnp.zeros((B, capacity), jnp.int32))


def _prefix_meta_write(meta, batch, cfg, offset: int):
    """Write this chunk's pos/seg into the capacity-length meta arrays at KV
    slot ``offset`` (unwritten slots stay seg=0 => masked everywhere)."""
    pos, seg = meta
    bp = batch["positions"]
    if cfg.mrope and bp.ndim == 3:
        bp = bp[..., 0]
    upd = lambda buf, x: jax.lax.dynamic_update_slice_in_dim(
        buf, x.astype(buf.dtype), offset, axis=1)
    return (upd(pos, bp), upd(seg, batch["segment_ids"]))


# ------------------------------------------------------------ executor ------
def run_group(cfg: ModelConfig, params, chunk_batches, *, k: int = 1,
              loss_scale: float = 1.0, grads=None,
              blockwise_threshold: int = 8192, stats: SchedulerStats = None,
              chunk_fn=None):
    """Run Algorithm 2 over one dependent-chunk group (or a singleton
    standalone chunk). Returns (total_loss, grads, stats).

    Static shapes: the KV prefix is allocated once at the group's bucketed
    capacity (`ss.prefix_capacity`) and each chunk's own K/V is written in at
    offset i*C, so every chunk step in a bucket shares one compiled
    executable (the unused tail keeps seg=0 and is exactly masked).

    chunk_fn: optional (params, prefix, batch) -> (loss, own) override —
    the context-parallel executor swaps in its shard_map ring trunk here;
    the Algorithm-2 schedule, StateStore threading and cotangent routing
    stay identical."""
    stats = stats or SchedulerStats()
    f = chunk_fn or _jitted_chunk_fn(cfg, blockwise_threshold)
    n = len(chunk_batches)
    B = chunk_batches[0]["tokens"].shape[0]
    C = chunk_batches[0]["tokens"].shape[1]

    cap = ss.prefix_capacity(n, C)
    prefix = ss.alloc_prefix(cfg, B, cap, jnp.dtype(cfg.dtype))
    meta = _prefix_meta_init(B, cap)
    prefixes, metas = [prefix], [meta]       # the StateStore (holds all K/V)
    for i, batch in enumerate(chunk_batches[:-1]):
        meta = _prefix_meta_write(meta, batch, cfg, i * C)
        metas.append(meta)

    vjps, owns, pending = {}, {}, {i: None for i in range(n)}
    total_loss = 0.0
    loss_cot = jnp.asarray(loss_scale, jnp.float32)

    def fwd(i, keep):
        batch = chunk_batch_with_prefix(chunk_batches[i], metas[i])
        if keep:
            (loss, own), vjp_fn = jax.vjp(
                lambda p, pre: f(p, pre, batch), params, prefixes[i])
            vjps[i] = vjp_fn
            stats.max_live_residuals = max(stats.max_live_residuals, len(vjps))
        else:
            loss, own = f(params, prefixes[i], batch)
        owns[i] = own
        return loss, own

    def bwd(i, grads):
        own_cot = pending.pop(i)
        if own_cot is None:
            own_cot = jax.tree.map(
                lambda x: None if x is None else jnp.zeros_like(x), owns[i],
                is_leaf=lambda x: x is None)
        gp, gpre = vjps.pop(i)((loss_cot, own_cot))
        grads = ss.tree_add(grads, gp)
        for j, contrib in ss.split_prefix_cot(cfg, gpre, i, C).items():
            pending[j] = ss.tree_add(pending[j], contrib)
        stats.backward_calls += 1
        return grads

    for ev in alg2_schedule(n, k):
        if ev[0] == "F":
            _, i, keep = ev
            loss, own = fwd(i, keep)
            if i + 1 < n:       # the last chunk's own K/V has no reader
                nxt = ss.write_own(cfg, prefixes[i], own, i * C)
                if len(prefixes) <= i + 1:
                    prefixes.append(nxt)
                else:
                    prefixes[i + 1] = nxt
            total_loss = total_loss + loss * loss_scale
            stats.forward_calls += 1
        elif ev[0] == "F2":
            _, i = ev
            fwd(i, keep=True)
            stats.recompute_calls += 1
        else:
            _, i = ev
            grads = bwd(i, grads)

    assert not vjps and all(v is None for v in pending.values())
    return total_loss, grads, stats


def _batch_loss_scale(groups, standalone) -> float:
    total_tokens = 0.0
    for g in groups:
        total_tokens += sum(float(np.sum(b["loss_mask"])) for b in g)
    total_tokens += sum(float(np.sum(b["loss_mask"])) for b in standalone)
    return 1.0 / max(total_tokens, 1.0)


def run_batch(cfg: ModelConfig, params, groups, standalone, *, k: int = 1,
              blockwise_threshold: int = 8192, mesh=None,
              plan_policy: str = "lpt", cp_threshold: int = 0):
    """One full training micro-iteration over the chunks of a sampled batch:
    every dependent group via Algorithm 2, every standalone chunk as a
    singleton group; gradients accumulate across all of them (paper Fig. 3).

    groups: list[list[chunk_batch]]; standalone: list[chunk_batch]
    Returns (mean_loss, grads, stats).

    mesh: optional jax mesh. With a "pipe" axis of size > 1 the batch runs
    on the (data x pipe [x seq]) K-retention rotation pipeline
    (`distributed.pipeline.run_batch_pipelined` — Algorithm 2 at pipeline
    scale, K bounding live residual chunk-states per stage). With a "seq"
    axis of size > 1 (and no pipe axis) the batch runs on the
    context-parallel ring executor (`distributed.context_parallel
    .run_batch_cp`: chunk tokens sharded over "seq", K/V circulating via
    ppermute; ``cp_threshold`` keeps short chunks off the ring). Otherwise,
    with >1 DP devices the batch is executed by the DP orchestrator
    (`_run_batch_dp`): the dp_balance planner assigns units to ranks and the
    work runs as batch-dim-sharded waves. With a 1-device mesh (or
    mesh=None) this is the plain single-device path — bit-for-bit the
    pre-DP behavior."""
    if mesh is not None and sharding.pipe_size(mesh) > 1:
        from repro.distributed import pipeline
        return pipeline.run_batch_pipelined(
            cfg, params, groups, standalone, mesh, k=k,
            blockwise_threshold=blockwise_threshold, plan_policy=plan_policy,
            cp_threshold=cp_threshold)
    if mesh is not None and sharding.seq_size(mesh) > 1:
        from repro.distributed import context_parallel
        return context_parallel.run_batch_cp(
            cfg, params, groups, standalone, mesh, k=k,
            blockwise_threshold=blockwise_threshold, plan_policy=plan_policy,
            cp_threshold=cp_threshold)
    if mesh is not None and sharding.dp_size(mesh) > 1:
        return _run_batch_dp(cfg, params, groups, standalone, mesh, k=k,
                             blockwise_threshold=blockwise_threshold,
                             plan_policy=plan_policy)
    scale = _batch_loss_scale(groups, standalone)
    grads = None
    loss = 0.0
    stats = SchedulerStats()
    for g in groups:
        l, grads, stats = run_group(cfg, params, g, k=k, loss_scale=scale,
                                    grads=grads, stats=stats,
                                    blockwise_threshold=blockwise_threshold)
        loss += l
    for c in standalone:
        l, grads, stats = run_group(cfg, params, [c], k=k, loss_scale=scale,
                                    grads=grads, stats=stats,
                                    blockwise_threshold=blockwise_threshold)
        loss += l
    return loss, grads, stats


# ------------------------------------------------------- DP orchestration ---
def dummy_chunk_row(like):
    """All-padding chunk row (segment_ids == 0 everywhere): fully masked in
    attention, zero loss_mask, so its loss and gradients are exactly zero."""
    return jax.tree.map(jnp.zeros_like, like)


def stack_chunk_rows(rows):
    """Merge per-rank (1, C, ...) chunk batches into one (R, C, ...) batch —
    row r is DP rank r's chunk for this slot."""
    keys = rows[0].keys()
    assert all(r.keys() == keys for r in rows), "non-uniform chunk keys"
    return {kk: jnp.concatenate([r[kk] for r in rows], axis=0)
            for kk in keys}


def stack_wave_slots(cfg: ModelConfig, wave, mesh):
    """One dp_balance wave -> its chunk-slot stream: a list of (R, C)
    stacked batches, one per slot, batch-dim sharded over the DP axes.
    Ranks whose unit is shorter than the wave's longest pad with dummy
    all-masked chunks (zero loss, zero grads, pure idle — the bubble the
    planner minimizes). Shared by the DP and pipeline executors so their
    padding/stacking semantics can never drift apart."""
    live = [u for u in wave if u is not None]
    n_max = max(u.n_chunks for u in live)
    template = live[0].payload[0]
    slots = []
    for i in range(n_max):
        rows = [u.payload[i] if (u is not None and i < u.n_chunks)
                else dummy_chunk_row(template) for u in wave]
        slots.append(sharding.dp_put(cfg, stack_chunk_rows(rows), mesh))
    return slots


def run_planned_waves(cfg: ModelConfig, params, units, mesh, *, k: int,
                      scale: float, blockwise_threshold: int = 8192,
                      plan_policy: str = "lpt", chunk_fn_for_wave=None,
                      wave_done=None):
    """Shared wave orchestration for the DP and context-parallel executors:
    plan the units onto ranks, stack each lockstep wave into (R, C) slots,
    run each wave through the Algorithm-2 executor. Returns
    (total_loss, grads, stats).

    chunk_fn_for_wave: optional (wave, slots) -> chunk_fn override for
    `run_group` (None = the default jitted chunk fn) — the CP executor
    swaps in its ring trunk per wave here.
    wave_done: optional (wave, slots, stats, n_fwd, n_bwd) callback after
    each wave (n_fwd counts forwards incl. recomputes) — used for ring-hop
    accounting."""
    plan = dp_balance.plan_assignment(units, sharding.dp_size(mesh),
                                      policy=plan_policy)
    waves, _ = dp_balance.wave_schedule(plan)

    params_r = sharding.replicate_put(mesh, params)
    grads, total_loss = None, 0.0
    stats = SchedulerStats()
    for wave in waves:
        slots = stack_wave_slots(cfg, wave, mesh)
        fn = chunk_fn_for_wave(wave, slots) if chunk_fn_for_wave else None
        f0 = stats.forward_calls + stats.recompute_calls
        b0 = stats.backward_calls
        l, grads, stats = run_group(cfg, params_r, slots, k=k,
                                    loss_scale=scale, grads=grads,
                                    stats=stats,
                                    blockwise_threshold=blockwise_threshold,
                                    chunk_fn=fn)
        if wave_done is not None:
            wave_done(wave, slots, stats,
                      stats.forward_calls + stats.recompute_calls - f0,
                      stats.backward_calls - b0)
        total_loss = total_loss + l
    return total_loss, grads, stats


def _run_batch_dp(cfg: ModelConfig, params, groups, standalone, mesh, *,
                  k: int = 1, blockwise_threshold: int = 8192,
                  plan_policy: str = "lpt"):
    """Data-parallel Algorithm 2 (paper's DP-balanced chunk-group training).

    The dp_balance planner assigns every dependent group / packed standalone
    chunk to a DP rank by token-work (LPT). Execution is lockstep *waves*:
    one work unit per rank per wave, each unit's chunk i stacked across ranks
    into a (R, C) batch whose batch dim is sharded over the mesh's data axes
    — so rank r's work physically runs on device r, params stay replicated,
    and the gradient psum across ranks is inserted by GSPMD when the vjp
    pulls the (replicated) param cotangent out of the (sharded) batch loss.
    Ranks whose unit is shorter than the wave's longest pad with dummy
    all-masked chunks: zero loss, zero grads, pure idle — the same bubble a
    real cluster would pay, which is what the planner minimizes.

    Numerically equivalent to the single-device path (same loss scale, same
    per-row math; fp32 summation order differs -> ~1e-6 relative). Caveat:
    with a MoE aux loss coefficient > 0, dummy rows add router aux terms the
    single-device path does not have (padding tokens already do today).
    """
    scale = _batch_loss_scale(groups, standalone)
    units = dp_balance.units_from_materialized(groups, standalone, k=k,
                                               static_shapes=True)
    return run_planned_waves(cfg, params, units, mesh, k=k, scale=scale,
                             blockwise_threshold=blockwise_threshold,
                             plan_policy=plan_policy)
