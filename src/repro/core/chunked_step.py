"""Algorithm 2 — state-aware chunk scheduling, executed with jax.vjp.

The scheduler is split into a *pure schedule generator* (`alg2_schedule`,
shared with the pipeline simulator and unit-tested against the paper) and an
*executor* that walks the schedule holding at most K chunks' vjp residuals
alive — that is the paper's "peak memory = K * ChunkSize" mechanism, realised
here as: at most K live `jax.vjp` closures (XLA residual buffers), with the
first N-K chunks forwarded twice (the second time producing residuals right
before their backward).

Gradients are accumulated across chunks (and across the K/V state reads —
`statestore.split_prefix_cot` routes each chunk's prefix gradient back to the
producing chunks), which makes the whole thing mathematically equivalent to a
full-sequence step; tests/test_chunked_equivalence.py asserts this to ~1e-5.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import dp_balance, planner
from repro.core import statestore as ss
from repro.core.planner import ExecutionPlan
from repro.distributed import sharding
from repro.models import api


# ------------------------------------------------------------ schedule ------
def alg2_schedule(n_chunks: int, k: int):
    """Events: ("F", i, keep_residuals), ("B", i), ("F2", i).
    Forward ascending; keep residuals only for the last K; backward descending;
    first N-K chunks re-forwarded immediately before their backward."""
    n, k = n_chunks, max(1, k)
    keep_from = max(n - k, 0)
    ev = [("F", i, i >= keep_from) for i in range(n)]
    ev += [("B", i) for i in reversed(range(keep_from, n))]
    for i in reversed(range(keep_from)):
        ev += [("F2", i), ("B", i)]
    return ev


@dataclasses.dataclass
class SchedulerStats:
    forward_calls: int = 0
    recompute_calls: int = 0
    backward_calls: int = 0
    max_live_residuals: int = 0
    ring_steps: int = 0       # context-parallel ppermute hops (0 without CP)
    # of ring_steps, the hops the double-buffered ring issues under a flash
    # kernel (dp_balance.overlapped_ring_hops; 0 when overlap is off)
    overlapped_hops: int = 0
    # per-wave cp actually executed ([] on the single-device path) — the
    # ExecutionPlan's heterogeneity made observable
    wave_cps: list = dataclasses.field(default_factory=list)
    # StateStore residency (statestore.PrefixStore accounting): peak
    # store-held device bytes, peak host-mirrored bytes, and host->device
    # bucket transfers issued (all 0 when offload is off and the store
    # keeps every version on device)
    resident_statestore_bytes: int = 0
    offloaded_statestore_bytes: int = 0
    statestore_prefetches: int = 0


# ---------------------------------------------------------- chunk fn --------
def token_nll_sum(logits, labels, loss_mask):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * loss_mask)


# Trace-time log of the jitted chunk fn: one entry per *Python retrace*
# (== per fresh XLA compile), recording the (prefix_capacity, chunk_len)
# shape signature. With the static-shape StateStore this stays O(#buckets)
# for a mixed batch; tests/test_compile_count.py pins that.
TRACE_EVENTS: list = []


def reset_trace_log():
    TRACE_EVENTS.clear()
    _jitted_chunk_fn.cache_clear()


@functools.lru_cache(maxsize=None)
def _jitted_chunk_fn(cfg: ModelConfig, blockwise_threshold: int):
    def f(params, prefix, batch):
        P = ss.prefix_len(cfg, prefix)
        TRACE_EVENTS.append((cfg.name, P, batch["tokens"].shape[1]))
        state = ss.assemble(cfg, prefix, batch)
        logits, new_state, aux = api.forward(
            cfg, params, batch, state, blockwise_threshold=blockwise_threshold)
        own = ss.slice_own(cfg, new_state, P)
        loss = token_nll_sum(logits, batch["labels"], batch["loss_mask"])
        loss = loss + aux["moe_aux"]
        return loss, own
    return jax.jit(f)


def chunk_batch_with_prefix(chunk_batch: dict, prefix_meta):
    """Attach prefix pos/seg (int arrays, non-differentiable) to the batch."""
    b = dict(chunk_batch)
    b["prefix_pos"], b["prefix_seg"] = prefix_meta
    return b


def _prefix_meta_init(B, capacity: int):
    return (jnp.zeros((B, capacity), jnp.int32),
            jnp.zeros((B, capacity), jnp.int32))


def _prefix_meta_write(meta, batch, cfg, offset: int):
    """Write this chunk's pos/seg into the capacity-length meta arrays at KV
    slot ``offset`` (unwritten slots stay seg=0 => masked everywhere)."""
    pos, seg = meta
    bp = batch["positions"]
    if cfg.mrope and bp.ndim == 3:
        bp = bp[..., 0]
    upd = lambda buf, x: jax.lax.dynamic_update_slice_in_dim(
        buf, x.astype(buf.dtype), offset, axis=1)
    return (upd(pos, bp), upd(seg, batch["segment_ids"]))


# ------------------------------------------------------------ executor ------
def run_group(cfg: ModelConfig, params, chunk_batches, *, k: int = 1,
              loss_scale: float = 1.0, grads=None,
              blockwise_threshold: int = 8192, stats: SchedulerStats = None,
              chunk_fn=None, offload_statestore: bool = False,
              prefetch_depth: int = 2):
    """Run Algorithm 2 over one dependent-chunk group (or a singleton
    standalone chunk). Returns (total_loss, grads, stats).

    Static shapes: the KV prefix is allocated once at the group's bucketed
    capacity (`ss.prefix_capacity`) and each chunk's own K/V is written in at
    offset i*C, so every chunk step in a bucket shares one compiled
    executable (the unused tail keeps seg=0 and is exactly masked).

    chunk_fn: optional (params, prefix, batch) -> (loss, own) override —
    the context-parallel executor swaps in its shard_map ring trunk here;
    the Algorithm-2 schedule, StateStore threading and cotangent routing
    stay identical.

    offload_statestore: host-offload cold prefix versions through
    `ss.PrefixStore` — the access schedule handed to the store is derived
    from the very `alg2_schedule` this loop walks, so prefetches land
    exactly when the F2 re-reads need them (`prefetch_depth` buckets
    in flight). Exactness is unchanged (tests pin <=1e-5 vs. off)."""
    stats = stats or SchedulerStats()
    f = chunk_fn or _jitted_chunk_fn(cfg, blockwise_threshold)
    n = len(chunk_batches)
    B = chunk_batches[0]["tokens"].shape[0]
    C = chunk_batches[0]["tokens"].shape[1]

    cap = ss.prefix_capacity(n, C)
    prefix = ss.alloc_prefix(cfg, B, cap, jnp.dtype(cfg.dtype))
    meta = _prefix_meta_init(B, cap)
    sched = alg2_schedule(n, k)
    access = [e[1] for e in sched if e[0] in ("F", "F2")]
    store = ss.PrefixStore(cfg, prefix, n, C, k, offload=offload_statestore,
                           prefetch_depth=prefetch_depth, schedule=access)
    metas = [meta]                 # int pos/seg versions (tiny next to K/V)
    for i, batch in enumerate(chunk_batches[:-1]):
        meta = _prefix_meta_write(meta, batch, cfg, i * C)
        metas.append(meta)

    vjps, owns, pending = {}, {}, {i: None for i in range(n)}
    total_loss = 0.0
    loss_cot = jnp.asarray(loss_scale, jnp.float32)

    def fwd(i, keep):
        batch = chunk_batch_with_prefix(chunk_batches[i], metas[i])
        pre = store.get(i)
        if keep:
            (loss, own), vjp_fn = jax.vjp(
                lambda p, q: f(p, q, batch), params, pre)
            vjps[i] = vjp_fn
            stats.max_live_residuals = max(stats.max_live_residuals, len(vjps))
        else:
            loss, own = f(params, pre, batch)
        owns[i] = own
        return loss, own

    def bwd(i, grads):
        own_cot = pending.pop(i)
        if own_cot is None:
            own_cot = jax.tree.map(
                lambda x: None if x is None else jnp.zeros_like(x), owns[i],
                is_leaf=lambda x: x is None)
        gp, gpre = vjps.pop(i)((loss_cot, own_cot))
        grads = ss.tree_add(grads, gp)
        for j, contrib in ss.split_prefix_cot(cfg, gpre, i, C).items():
            pending[j] = ss.tree_add(pending[j], contrib)
        stats.backward_calls += 1
        return grads

    for ev in sched:
        if ev[0] == "F":
            _, i, keep = ev
            loss, own = fwd(i, keep)
            if i + 1 < n:       # the last chunk's own K/V has no reader
                store.put(i + 1, ss.write_own(cfg, store.get(i), own, i * C),
                          own)
            total_loss = total_loss + loss * loss_scale
            stats.forward_calls += 1
        elif ev[0] == "F2":
            _, i = ev
            fwd(i, keep=True)
            stats.recompute_calls += 1
        else:
            _, i = ev
            store.drop_device()   # ascending sweep over; closures own theirs
            grads = bwd(i, grads)

    assert not vjps and all(v is None for v in pending.values())
    stats.resident_statestore_bytes = max(stats.resident_statestore_bytes,
                                          store.stats.device_bytes_peak)
    stats.offloaded_statestore_bytes = max(stats.offloaded_statestore_bytes,
                                           store.stats.host_bytes)
    stats.statestore_prefetches += store.stats.prefetches
    return total_loss, grads, stats


def _batch_loss_scale(groups, standalone) -> float:
    total_tokens = 0.0
    for g in groups:
        total_tokens += sum(float(np.sum(b["loss_mask"])) for b in g)
    total_tokens += sum(float(np.sum(b["loss_mask"])) for b in standalone)
    return 1.0 / max(total_tokens, 1.0)


def coerce_plan(batch, plan, mesh, *, k, blockwise_threshold, plan_policy,
                cp_threshold, where: str):
    """-> (groups, standalone, ExecutionPlan). The executors' two calling
    conventions, disambiguated in one place:

      new:    where(cfg, params, (groups, standalone), plan)
      legacy: where(cfg, params, groups, standalone, [mesh,] k=..,
                    mesh=.., plan_policy=.., cp_threshold=..,
                    blockwise_threshold=..)

    A legacy call (4th positional is the standalone list, or any old kwarg
    is present) emits DeprecationWarning and builds the equivalent
    ExecutionPlan via `planner.plan_batch(policy=plan_policy)` — the
    legacy "lpt"/"round_robin" policies reproduce the pre-planner waves
    bit-for-bit. mesh=None legacy calls get the trivial single-device plan
    without any unit costing (no host readbacks the old path didn't do)."""
    legacy = (isinstance(plan, list) or mesh is not None
              or any(v is not None for v in (k, blockwise_threshold,
                                             plan_policy, cp_threshold)))
    if legacy:
        warnings.warn(
            f"{where}(cfg, params, groups, standalone, mesh=..., k=..., "
            "plan_policy=..., cp_threshold=..., blockwise_threshold=...) is "
            "deprecated: build an ExecutionPlan with "
            "repro.core.planner.plan_batch(groups, standalone, mesh, k=..., "
            f"policy=...) and call {where}(cfg, params, "
            "(groups, standalone), plan)", DeprecationWarning, stacklevel=3)
        groups = batch
        standalone = plan if isinstance(plan, list) else []
        k = 1 if k is None else k
        bt = 8192 if blockwise_threshold is None else blockwise_threshold
        if mesh is None:
            plan = ExecutionPlan(data=1, pipe=1, seq=1, chunk_size=0, k=k,
                                 waves=[], policy=plan_policy or "lpt",
                                 blockwise_threshold=bt)
        else:
            plan = planner.plan_batch(groups, standalone, mesh, k=k,
                                      policy=plan_policy or "lpt",
                                      cp_threshold=cp_threshold or 0,
                                      blockwise_threshold=bt)
        return groups, standalone, plan
    groups, standalone = batch
    if plan is None:
        plan = ExecutionPlan(data=1, pipe=1, seq=1, chunk_size=0, k=1,
                             waves=[], policy="solve")
    if plan.world_size > 1 and plan.mesh is None:
        raise ValueError(f"{where}: plan spans {plan.world_size} devices but "
                         "carries no mesh — build it with plan_batch(..., "
                         "mesh=<jax mesh>)")
    return groups, standalone, plan


def run_batch(cfg: ModelConfig, params, batch, plan: ExecutionPlan = None,
              *, k: int = None, blockwise_threshold: int = None, mesh=None,
              plan_policy: str = None, cp_threshold: int = None):
    """One full training micro-iteration over the chunks of a sampled batch:
    every dependent group via Algorithm 2, every standalone chunk as a
    singleton group; gradients accumulate across all of them (paper Fig. 3).

    batch: (groups, standalone) — list[list[chunk_batch]], [chunk_batch].
    plan:  ExecutionPlan from `repro.core.planner.plan_batch` (None = the
           trivial single-device plan). The plan carries EVERYTHING the old
           kwargs did — mesh shape, per-wave cp groups, chunk assignments,
           K, ChunkSize, blockwise_threshold — and this function only
           dispatches on it. Returns (mean_loss, grads, stats).

    Dispatch by the plan's mesh: a "pipe" axis > 1 runs the (data x pipe
    [x seq]) K-retention rotation pipeline (`distributed.pipeline
    .run_batch_pipelined`); a "seq" axis > 1 (no pipe) runs the
    context-parallel executor (`distributed.context_parallel.run_batch_cp`)
    — per the plan, each wave either rides the "seq" ring (cp > 1) or packs
    cp=1 units over the whole data x seq device block without paying any
    ring hops. Plain DP runs the planned waves batch-dim-sharded; a
    1-device plan (or plan=None) is the plain single-device path —
    bit-for-bit the pre-DP behavior.

    The legacy signature ``run_batch(cfg, params, groups, standalone,
    k=..., mesh=..., plan_policy=..., cp_threshold=...)`` still works via a
    deprecation shim that builds the equivalent ExecutionPlan (see
    `coerce_plan`)."""
    groups, standalone, plan = coerce_plan(
        batch, plan, mesh, k=k, blockwise_threshold=blockwise_threshold,
        plan_policy=plan_policy, cp_threshold=cp_threshold,
        where="run_batch")
    mesh = plan.mesh
    if mesh is not None and sharding.pipe_size(mesh) > 1:
        from repro.distributed import pipeline
        return pipeline.run_batch_pipelined(cfg, params,
                                            (groups, standalone), plan)
    if mesh is not None and sharding.seq_size(mesh) > 1:
        from repro.distributed import context_parallel
        return context_parallel.run_batch_cp(cfg, params,
                                             (groups, standalone), plan)
    if mesh is not None and sharding.dp_size(mesh) > 1:
        scale = _batch_loss_scale(groups, standalone)
        return run_planned_waves(cfg, params, plan, scale=scale)
    scale = _batch_loss_scale(groups, standalone)
    grads = None
    loss = 0.0
    stats = SchedulerStats()
    bt = plan.blockwise_threshold
    for g in groups:
        l, grads, stats = run_group(cfg, params, g, k=plan.k,
                                    loss_scale=scale, grads=grads,
                                    stats=stats, blockwise_threshold=bt,
                                    offload_statestore=plan.offload_statestore,
                                    prefetch_depth=plan.prefetch_depth)
        loss += l
    for c in standalone:
        l, grads, stats = run_group(cfg, params, [c], k=plan.k,
                                    loss_scale=scale, grads=grads,
                                    stats=stats, blockwise_threshold=bt,
                                    offload_statestore=plan.offload_statestore,
                                    prefetch_depth=plan.prefetch_depth)
        loss += l
    return loss, grads, stats


# ------------------------------------------------------- DP orchestration ---
def dummy_chunk_row(like):
    """All-padding chunk row (segment_ids == 0 everywhere): fully masked in
    attention, zero loss_mask, so its loss and gradients are exactly zero."""
    return jax.tree.map(jnp.zeros_like, like)


def stack_chunk_rows(rows):
    """Merge per-rank (1, C, ...) chunk batches into one (R, C, ...) batch —
    row r is DP rank r's chunk for this slot."""
    keys = rows[0].keys()
    assert all(r.keys() == keys for r in rows), "non-uniform chunk keys"
    return {kk: jnp.concatenate([r[kk] for r in rows], axis=0)
            for kk in keys}


def stack_wave_slots(cfg: ModelConfig, wave, mesh, *, cp: int = 1):
    """One planned wave's slot list -> its chunk-slot stream: a list of
    (R, C) stacked batches, one per lockstep slot, placed per the wave's cp
    (`sharding.wave_put`: ring waves shard rows over the DP axes and tokens
    over "seq"; cp=1 waves on a seq mesh pack rows over the whole
    data x seq block and leave tokens whole — no ring hops). Ranks whose
    unit is shorter than the wave's longest pad with dummy all-masked
    chunks (zero loss, zero grads, pure idle — the bubble the planner
    minimizes). Shared by the DP, CP and pipeline executors so their
    padding/stacking semantics can never drift apart."""
    live = [u for u in wave if u is not None]
    n_max = max(u.n_chunks for u in live)
    template = live[0].payload[0]
    slots = []
    for i in range(n_max):
        rows = [u.payload[i] if (u is not None and i < u.n_chunks)
                else dummy_chunk_row(template) for u in wave]
        slots.append(sharding.wave_put(cfg, stack_chunk_rows(rows), mesh,
                                       cp=cp))
    return slots


def run_planned_waves(cfg: ModelConfig, params, plan: ExecutionPlan, *,
                      scale: float, chunk_fn_for_wave=None, wave_done=None):
    """Shared wave orchestration for the DP and context-parallel executors:
    walk the ExecutionPlan's waves, stack each into (R, C) slots placed for
    its cp, run each through the Algorithm-2 executor. Returns
    (total_loss, grads, stats). Gradient math is invariant to the plan
    (grads sum linearly and dummy rows contribute exactly zero), so ANY
    plan — legacy lpt, solved heterogeneous — matches single-device.

    chunk_fn_for_wave: optional (wave: WavePlan, slots) -> chunk_fn override
    for `run_group` (None = the default jitted chunk fn) — the CP executor
    swaps in its ring trunk on cp > 1 waves here.
    wave_done: optional (wave, slots, stats, n_fwd, n_bwd) callback after
    each wave (n_fwd counts forwards incl. recomputes) — used for ring-hop
    accounting."""
    mesh = plan.mesh
    params_r = sharding.replicate_put(mesh, params)
    grads, total_loss = None, 0.0
    stats = SchedulerStats()
    for wave in plan.waves:
        slots = stack_wave_slots(cfg, wave.slots, mesh, cp=wave.cp)
        fn = chunk_fn_for_wave(wave, slots) if chunk_fn_for_wave else None
        f0 = stats.forward_calls + stats.recompute_calls
        b0 = stats.backward_calls
        l, grads, stats = run_group(
            cfg, params_r, slots, k=plan.k, loss_scale=scale, grads=grads,
            stats=stats, blockwise_threshold=plan.blockwise_threshold,
            chunk_fn=fn, offload_statestore=plan.offload_statestore,
            prefetch_depth=plan.prefetch_depth)
        stats.wave_cps.append(wave.cp)
        if wave_done is not None:
            wave_done(wave, slots, stats,
                      stats.forward_calls + stats.recompute_calls - f0,
                      stats.backward_calls - b0)
        total_loss = total_loss + l
    return total_loss, grads, stats
