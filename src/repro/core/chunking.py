"""Algorithm 1 — ChunkConstruction.

Given a batch of variable-length sequences and a ChunkSize:
  * sequences longer than ChunkSize are split into ceil(L/C) *dependent*
    chunks (a dependent group, processed with the state-aware scheduler);
  * the remaining short sequences are bin-packed into the fewest bins of
    capacity ChunkSize (the paper's minimal-BinCnt loop), each bin becoming a
    *standalone* packed chunk.

Chunks are then materialised into fixed-shape arrays (tokens / labels /
segment_ids / positions / loss_mask, all padded to ChunkSize) so every chunk
hits the same jit signature.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def sample_lengths(dist="paper_eval", n: int = 1, seed: int = 0, *,
                   min_len: int = 16, max_len: Optional[int] = None) -> list:
    """Sample ``n`` sequence lengths from the paper's long-tail distributions.

    ``dist``: ``"paper_eval"`` (Table 2), ``"lmsys"`` (Table 1), or an explicit
    ``[(upper_bound, cdf), ...]`` list. The single public entry point for
    long-tail lengths — the chunk planner benchmarks, the serving arrival
    simulator and `benchmarks/length_distribution.py` all draw from here so
    they stay calibrated to the same CDFs.
    """
    from repro.data.synthetic import (LMSYS_CDF, LongTailSampler,
                                      PAPER_EVAL_CDF)
    if isinstance(dist, str):
        try:
            cdf = {"paper_eval": PAPER_EVAL_CDF, "lmsys": LMSYS_CDF}[dist]
        except KeyError:
            raise ValueError(f"unknown length distribution {dist!r} "
                             "(want 'paper_eval', 'lmsys' or a CDF "
                             "list)") from None
    else:
        cdf = dist
    sampler = LongTailSampler(cdf, min_len=min_len, seed=seed, max_len=max_len)
    return sampler.sample_batch_lengths(n)


@dataclasses.dataclass(frozen=True)
class ChunkItem:
    seq_id: int
    start: int          # token offset within the original sequence
    length: int


@dataclasses.dataclass
class Chunk:
    items: list         # list[ChunkItem]
    chunk_size: int
    group: Optional[int] = None      # seq_id for dependent chunks, else None
    index_in_group: int = 0
    group_size: int = 1

    @property
    def dependent(self) -> bool:
        return self.group is not None

    @property
    def tokens_used(self) -> int:
        return sum(it.length for it in self.items)


def _first_fit_decreasing(lengths, ids, capacity, max_bins):
    """Try to pack (id, length) into <= max_bins bins. Returns bins or None."""
    order = sorted(range(len(lengths)), key=lambda i: -lengths[i])
    bins, space = [], []
    for i in order:
        l = lengths[i]
        placed = False
        for b in range(len(bins)):
            if space[b] >= l:
                bins[b].append(ids[i])
                space[b] -= l
                placed = True
                break
        if not placed:
            if len(bins) == max_bins:
                return None
            bins.append([ids[i]])
            space.append(capacity - l)
    return bins


def construct_chunks(lengths: dict, chunk_size: int) -> list:
    """lengths: {seq_id: length}. Returns list[Chunk] — dependent groups first
    (ascending index), then packed standalone chunks (Fig. 4 layout)."""
    assert chunk_size > 0
    long_ids = [s for s, l in lengths.items() if l > chunk_size]
    short_ids = [s for s, l in lengths.items() if 0 < l <= chunk_size]

    chunks = []
    for sid in sorted(long_ids):
        l = lengths[sid]
        n = -(-l // chunk_size)
        for j in range(n):
            start = j * chunk_size
            chunks.append(Chunk(
                items=[ChunkItem(sid, start, min(chunk_size, l - start))],
                chunk_size=chunk_size, group=sid, index_in_group=j,
                group_size=n))

    if short_ids:
        short_lens = [lengths[s] for s in short_ids]
        lo = max(1, -(-sum(short_lens) // chunk_size))
        bins = None
        for bin_cnt in range(lo, len(short_ids) + 1):   # Alg. 1 lines 8-10
            bins = _first_fit_decreasing(short_lens, short_ids, chunk_size,
                                         bin_cnt)
            if bins is not None:
                break
        assert bins is not None
        for b in bins:
            chunks.append(Chunk(
                items=[ChunkItem(s, 0, lengths[s]) for s in b],
                chunk_size=chunk_size))
    return chunks


def group_chunks(chunks):
    """-> (dependent_groups: dict[group_id, list[Chunk] ordered],
           standalone: list[Chunk])."""
    groups, standalone = {}, []
    for c in chunks:
        if c.dependent:
            groups.setdefault(c.group, []).append(c)
        else:
            standalone.append(c)
    for g in groups.values():
        g.sort(key=lambda c: c.index_in_group)
    return groups, standalone


def materialize_chunk(chunk: Chunk, sequences: dict, pad_id: int = 0):
    """sequences: {seq_id: np.ndarray int32 tokens}. Returns a dict of
    (1, chunk_size) arrays: tokens, labels, segment_ids, positions, loss_mask.

    Labels are next-token within the ORIGINAL sequence, so a dependent chunk's
    last token is supervised by the first token of the next chunk (no
    boundary-token loss is lost by splitting).
    """
    C = chunk.chunk_size
    tokens = np.full((C,), pad_id, np.int32)
    labels = np.full((C,), pad_id, np.int32)
    seg = np.zeros((C,), np.int32)
    pos = np.zeros((C,), np.int32)
    mask = np.zeros((C,), np.float32)

    off = 0
    for local_id, it in enumerate(chunk.items, start=1):
        s = np.asarray(sequences[it.seq_id])
        sl = s[it.start: it.start + it.length]
        tokens[off: off + it.length] = sl
        lab = s[it.start + 1: it.start + it.length + 1]
        labels[off: off + len(lab)] = lab
        m = np.ones((it.length,), np.float32)
        if len(lab) < it.length:        # sequence ends inside this chunk
            m[-1] = 0.0
        mask[off: off + it.length] = m
        seg[off: off + it.length] = (1 if chunk.dependent else local_id)
        pos[off: off + it.length] = np.arange(it.start, it.start + it.length)
        off += it.length

    return {
        "tokens": tokens[None], "labels": labels[None],
        "segment_ids": seg[None], "positions": pos[None],
        "loss_mask": mask[None],
    }
