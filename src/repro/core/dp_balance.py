"""DP-balance planner — Algorithm 2 across data-parallel ranks.

The paper's core systems claim is that variable-length batches create load
imbalance under data parallelism: a rank that drew the 256K-token tail
sequence does quadratically more attention work than a rank full of <1K
chat turns, and every other rank idles at the gradient all-reduce. This
module plans *which rank runs which chunk work* so that per-rank **token
work** (not sequence count) is balanced.

Units of assignment are the outputs of Algorithm 1:
  * a dependent chunk group (one long sequence's chunks — indivisible, the
    StateStore threads K/V through the whole group on one rank);
  * a packed standalone chunk (bin of short sequences).

Cost model (paper §3): execution time per chunk is linear in tokens plus a
quadratic attention term — for dependent chunk ``i`` the queries attend to
the full ``i*C`` prefix, for a packed chunk each segment only attends to
itself. Backward costs 2x forward, and the first ``N-K`` chunks of a group
pay one recompute forward (Algorithm 2).

Policies:
  * ``lpt``        — greedy Longest-Processing-Time: sort units by work
                     descending, always assign to the least-loaded rank
                     (4/3-approx of the optimal makespan);
  * ``round_robin``— the naive baseline (what sequence-count DP does).

``wave_schedule`` is the simulator bridge: the SPMD executor
(core/chunked_step.py) runs the plan as lockstep *waves* — one work unit per
rank per wave, shorter units padded with dummy chunks — so padded-slot waste
and the max/min work ratio are exactly the imbalance a real mesh would pay.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Optional

import numpy as np


# ---------------------------------------------------- capacity bucketing ----
def prefix_capacity(n_chunks: int, chunk_size: int) -> int:
    """KV-prefix capacity of the static-shape StateStore for an n-chunk
    group: the max prefix any chunk reads is (n-1)*C; bucket that chunk count
    to the next power of two so mixed group lengths collapse onto a handful
    of compiled shapes. Pure int math — shared by the planner's cost model
    and core/statestore.py (which owns the actual buffers)."""
    need = n_chunks - 1
    if need <= 0 or chunk_size <= 0:
        return 0
    return (1 << (need - 1).bit_length()) * chunk_size


# ------------------------------------------------------------ cost model ----
ATTN_HORIZON = 4096     # tokens at which the quadratic term matches linear


def chunk_token_work(tokens_used: int, prefix_len: int, seg_lengths=None, *,
                     horizon: int = ATTN_HORIZON) -> float:
    """Forward cost of one chunk in token-work units.

    tokens_used: real (non-pad) tokens in the chunk.
    prefix_len:  StateStore prefix this chunk attends to (dependent chunks).
    seg_lengths: per-segment lengths for packed standalone chunks — each
                 segment only attends within itself.
    """
    t = float(tokens_used)
    if seg_lengths is not None:
        quad = float(sum(l * l for l in seg_lengths))
    else:
        quad = t * (prefix_len + t)
    return t + quad / horizon


@dataclasses.dataclass(frozen=True)
class WorkUnit:
    """One indivisible piece of DP work: a dependent group or a standalone
    packed chunk. ``payload`` is opaque to the planner (the executor stores
    its list of materialized chunk batches there). ``cp`` is THIS unit's
    context-parallel degree — heterogeneous plans give different units
    different cp, so it lives on the unit, not as one global knob — and
    ``work`` is already divided by it (a CP group acts as one fast logical
    rank). ``ring`` (== cp > 1) marks units the context-parallel executor
    runs sharded over the "seq" axis; non-ring units replicate over "seq"
    (or pack the idle "seq" ranks) and keep their full cost."""
    kind: str                    # "group" | "standalone"
    key: Any                     # group id / standalone index (for reports)
    n_chunks: int
    work: float
    payload: Any = None
    ring: bool = False
    cp: int = 1

    def __repr__(self):
        return (f"WorkUnit({self.kind}:{self.key}, n={self.n_chunks}, "
                f"work={self.work:.1f}"
                f"{f', cp={self.cp}' if self.ring else ''})")


def cp_eligible(n_chunks: int, chunk_size: int, cp: int,
                cp_threshold: int) -> bool:
    """Whether a unit runs on the ring: CP pays ppermute latency every hop,
    which only amortizes on long-tail chunk spans. ``cp_threshold`` is the
    minimum unit token span (n_chunks * ChunkSize — the static-shape span
    the executor actually computes); 0 means every unit rides the ring."""
    return cp > 1 and n_chunks * chunk_size >= cp_threshold


def ring_hops(n_fwd: int, n_bwd: int, cp: int, n_layers: int = 1) -> int:
    """ppermute hops for ``n_fwd`` forward (incl. recompute) and ``n_bwd``
    backward chunk executions on a cp-rank ring: cp-1 K/V rotations per
    forward, cp per backward (the dk/dv accumulator takes one extra hop
    home), per attention layer. Single source of truth for the ring cost —
    the CP executors' ``stats.ring_steps`` and the analytic
    `ring_step_count` both derive from it."""
    if cp <= 1:
        return 0
    return n_layers * ((cp - 1) * n_fwd + cp * n_bwd)


def ring_step_count(n_chunks: int, cp: int, k: int = 1,
                    n_layers: int = 1) -> int:
    """Analytic `ring_hops` for one ring unit under Algorithm 2: every chunk
    pays one forward + one backward, and the first N-K pay one recompute
    forward."""
    n = n_chunks
    rec = max(n - max(1, k), 0)
    return ring_hops(n + rec, n, cp, n_layers)


def overlapped_ring_hops(n_fwd: int, n_bwd: int, cp: int,
                         n_layers: int = 1) -> int:
    """Of `ring_hops`, the hops the double-buffered ring issues BEFORE the
    kernel that hides them: the cp-1 K/V prefetch rotations of every forward
    and every backward. The remaining ``n_layers * n_bwd`` hops (the dk/dv
    accumulator's final hop home per backward) consume the hop's kernel
    output and stay exposed to dataflow. The executors report this in
    ``stats.overlapped_hops`` when the plan runs with ring overlap on."""
    if cp <= 1:
        return 0
    return n_layers * (cp - 1) * (n_fwd + n_bwd)


# Fixed per-ppermute-hop latency (token units — a blocking neighbor
# collective costs the equivalent of ~512 tokens of trunk compute) and the
# bandwidth cost of moving one K/V token around the ring. ONE home for these
# constants: `ring_comm_cost` below is the canonical serial comm formula the
# heterogeneous solver (core/planner.py, which re-exports both constants and
# layers overlap-awareness on top) and any cp costing here must share, so
# the solver and the wave packer can never rank configs differently
# (tests/test_planner.py pins the agreement).
RING_LATENCY = 512.0
RING_BW = 0.02


def ring_comm_cost(n_chunks: int, chunk_size: int, cp: int,
                   k: int = 1) -> float:
    """Serial (un-overlapped) communication cost of running one ring unit
    through Algorithm 2: ``ring_step_count`` ppermute hops (the executors'
    ``stats.ring_steps`` with n_layers=1), each paying fixed latency + the
    bandwidth cost of the circulating (cap + C)/cp K/V shard."""
    if cp <= 1:
        return 0.0
    hops = ring_step_count(n_chunks, cp, k=k)
    shard = (prefix_capacity(n_chunks, chunk_size) + chunk_size) / cp
    return hops * (RING_LATENCY + RING_BW * shard)


def unit_work(chunk_works, k: int = 1) -> float:
    """Full Algorithm-2 cost of a unit: every chunk pays F + 2F (backward);
    the first N-K chunks pay one recompute forward."""
    w = list(chunk_works)
    keep_from = max(len(w) - max(k, 1), 0)
    return 3.0 * sum(w) + sum(w[:keep_from])


def _cp_adjust(work: float, n_chunks: int, chunk_size: int, cp: int,
               cp_threshold: int, cp_for=None):
    """-> (work, ring, unit_cp). A ring unit's span is token-sharded over
    its cp devices, so the CP group behaves as one logical rank at 1/cp the
    cost. ``cp_for`` (a ``(n_chunks, chunk_size) -> int`` callable)
    overrides the global cp/threshold gate with a per-unit degree —
    heterogeneous plans assign different cp to different units and the
    imbalance/makespan reports must cost each unit at ITS degree, not one
    global one."""
    if cp_for is not None:
        c = max(1, int(cp_for(n_chunks, chunk_size)))
        return work / c, c > 1, c
    if cp_eligible(n_chunks, chunk_size, cp, cp_threshold):
        return work / cp, True, cp
    return work, False, 1


def units_from_chunks(groups: dict, standalone: list, *, k: int = 1,
                      horizon: int = ATTN_HORIZON,
                      static_shapes: bool = False, cp: int = 1,
                      cp_threshold: int = 0, cp_for=None) -> list:
    """Build WorkUnits from Algorithm-1 output (`chunking.group_chunks`).

    groups: {group_id: [Chunk ordered]}; standalone: [Chunk].
    static_shapes: cost dependent chunks at the capacity-padded KV length
    (what the static-shape StateStore actually computes — masked slots still
    burn FLOPs) instead of the exact grow-by-C prefix.
    cp/cp_threshold: one global context-parallel degree + ring-eligibility
    span (see `cp_eligible`). cp_for: per-unit override, ``(n_chunks,
    chunk_size) -> cp`` — use this to cost a heterogeneous (per-wave cp)
    plan; the returned units carry their own ``cp``."""
    units = []
    for gid, chunks in groups.items():
        cap = prefix_capacity(len(chunks), chunks[0].chunk_size)
        works = [chunk_token_work(c.tokens_used,
                                  cap if static_shapes
                                  else c.index_in_group * c.chunk_size,
                                  horizon=horizon)
                 for c in chunks]
        w, ring, ucp = _cp_adjust(unit_work(works, k=k), len(chunks),
                                  chunks[0].chunk_size, cp, cp_threshold,
                                  cp_for)
        units.append(WorkUnit("group", gid, len(chunks), w, payload=chunks,
                              ring=ring, cp=ucp))
    for idx, c in enumerate(standalone):
        w = chunk_token_work(c.tokens_used, 0,
                             seg_lengths=[it.length for it in c.items],
                             horizon=horizon)
        w, ring, ucp = _cp_adjust(unit_work([w], k=k), 1, c.chunk_size, cp,
                                  cp_threshold, cp_for)
        units.append(WorkUnit("standalone", idx, 1, w, payload=[c],
                              ring=ring, cp=ucp))
    return units


def _batch_chunk_work(chunk_batch, index_in_group: int, dependent: bool, *,
                      horizon: int = ATTN_HORIZON,
                      prefix_override=None) -> float:
    """Token work of one *materialized* chunk batch (row 0 of (1,C) arrays)."""
    seg = np.asarray(chunk_batch["segment_ids"])[0]
    t = int((seg > 0).sum())
    C = int(seg.shape[0])
    if dependent:
        prefix = (prefix_override if prefix_override is not None
                  else index_in_group * C)
        return chunk_token_work(t, prefix, horizon=horizon)
    seg_lens = [int((seg == s).sum()) for s in np.unique(seg) if s > 0]
    return chunk_token_work(t, 0, seg_lengths=seg_lens, horizon=horizon)


def units_from_materialized(group_batches: list, standalone_batches: list, *,
                            k: int = 1, horizon: int = ATTN_HORIZON,
                            static_shapes: bool = False, cp: int = 1,
                            cp_threshold: int = 0, cp_for=None) -> list:
    """Build WorkUnits from `launch.train.build_host_batches` output:
    group_batches: list[list[chunk_batch dict]]; standalone: [chunk_batch].
    Prefer host (numpy) batches — device arrays cost one blocking readback
    per chunk here. static_shapes / cp / cp_threshold / cp_for: see
    `units_from_chunks`."""
    units = []
    for gid, batches in enumerate(group_batches):
        cap = None
        C = int(np.asarray(batches[0]["segment_ids"]).shape[1])
        if static_shapes and batches:
            cap = prefix_capacity(len(batches), C)
        works = [_batch_chunk_work(b, i, True, horizon=horizon,
                                   prefix_override=cap)
                 for i, b in enumerate(batches)]
        w, ring, ucp = _cp_adjust(unit_work(works, k=k), len(batches), C, cp,
                                  cp_threshold, cp_for)
        units.append(WorkUnit("group", gid, len(batches), w,
                              payload=batches, ring=ring, cp=ucp))
    for idx, b in enumerate(standalone_batches):
        C = int(np.asarray(b["segment_ids"]).shape[1])
        w = _batch_chunk_work(b, 0, False, horizon=horizon)
        w, ring, ucp = _cp_adjust(unit_work([w], k=k), 1, C, cp,
                                  cp_threshold, cp_for)
        units.append(WorkUnit("standalone", idx, 1, w, payload=[b],
                              ring=ring, cp=ucp))
    return units


# --------------------------------------------------------------- planner ----
@dataclasses.dataclass
class DPPlan:
    world_size: int
    rank_units: list                 # list[list[WorkUnit]], ordered streams
    policy: str

    @property
    def rank_work(self) -> list:
        return [sum(u.work for u in units) for units in self.rank_units]

    @property
    def max_work(self) -> float:
        return max(self.rank_work) if self.world_size else 0.0

    @property
    def imbalance(self) -> float:
        """max-rank work relative to perfect balance (1.0 = ideal). This is
        the iteration-time slowdown every other rank pays at the gradient
        all-reduce."""
        total = sum(self.rank_work)
        if total <= 0:
            return 1.0
        return self.max_work * self.world_size / total

    @property
    def max_min_ratio(self) -> float:
        w = self.rank_work
        lo = min(w)
        return float("inf") if lo <= 0 else max(w) / lo


def plan_assignment(units: list, world_size: int, *,
                    policy: str = "lpt") -> DPPlan:
    """Assign WorkUnits to ``world_size`` rank streams.

    Deterministic: ties break on (work desc, kind, key) for sorting and on
    rank index inside the heap. Each rank's stream is ordered largest-first
    so `wave_schedule` aligns big units with big units across ranks."""
    assert world_size >= 1
    rank_units = [[] for _ in range(world_size)]
    if policy == "lpt":
        order = sorted(units, key=lambda u: (-u.work, -u.n_chunks,
                                             u.kind, str(u.key)))
        heap = [(0.0, r) for r in range(world_size)]
        heapq.heapify(heap)
        for u in order:
            load, r = heapq.heappop(heap)
            rank_units[r].append(u)
            heapq.heappush(heap, (load + u.work, r))
    elif policy == "round_robin":
        for i, u in enumerate(units):
            rank_units[i % world_size].append(u)
    else:
        raise ValueError(f"unknown policy {policy!r}")
    for stream in rank_units:
        stream.sort(key=lambda u: (-u.n_chunks, -u.work, u.kind, str(u.key)))
    return DPPlan(world_size, rank_units, policy)


# ------------------------------------------------------- wave simulator -----
@dataclasses.dataclass
class WaveStats:
    n_waves: int
    total_slots: int                 # chunk-slots executed incl. padding
    padded_slots: int                # dummy chunk-slots (rank idle)
    max_wave_chunks: list            # per-wave slot count (max n over ranks)

    @property
    def padded_fraction(self) -> float:
        return self.padded_slots / self.total_slots if self.total_slots else 0.0


def wave_schedule(plan: DPPlan):
    """-> (waves, WaveStats). Each wave is a list of length world_size of
    Optional[WorkUnit]: the unit each rank executes in lockstep. The executor
    pads every unit in a wave to the wave's max chunk count with dummy
    chunks, so `padded_slots` is exactly the compute wasted to imbalance."""
    n_waves = max((len(s) for s in plan.rank_units), default=0)
    waves, padded, per_wave = [], 0, []
    for w in range(n_waves):
        wave = [s[w] if w < len(s) else None for s in plan.rank_units]
        n_max = max(u.n_chunks for u in wave if u is not None)
        padded += sum(n_max - (u.n_chunks if u else 0) for u in wave)
        per_wave.append(n_max)
        waves.append(wave)
    total = sum(per_wave) * plan.world_size
    return waves, WaveStats(n_waves, total, padded, per_wave)


def compare_policies(units: list, world_size: int,
                     policies=("round_robin", "lpt"), *,
                     cp_for=None, chunk_size: int = 0) -> dict:
    """Benchmark hook: plan under each policy, report imbalance metrics.

    Heterogeneous plans: units may carry different per-unit ``cp`` (built
    with ``units_from_chunks(..., cp_for=...)``), or pass ``cp_for`` +
    ``chunk_size`` here to re-cost the given units at per-unit degrees
    before planning. Either way every unit is costed at ITS cp — not one
    global degree — so ``max_rank_work``/``imbalance`` stay correct for
    mixed-cp batches; ``ring_work_fraction`` reports how much of the total
    work rides a ring."""
    if cp_for is not None:
        units = [dataclasses.replace(
            u, work=u.work * u.cp / max(1, int(cp_for(u.n_chunks,
                                                      chunk_size))),
            cp=max(1, int(cp_for(u.n_chunks, chunk_size))),
            ring=int(cp_for(u.n_chunks, chunk_size)) > 1)
            for u in units]
    total = sum(u.work for u in units)
    ring_work = sum(u.work for u in units if u.cp > 1)
    out = {}
    for pol in policies:
        plan = plan_assignment(units, world_size, policy=pol)
        _, ws = wave_schedule(plan)
        out[pol] = {
            "max_rank_work": plan.max_work,
            "imbalance": plan.imbalance,
            "max_min_ratio": plan.max_min_ratio,
            "n_waves": ws.n_waves,
            "padded_slot_fraction": ws.padded_fraction,
            "ring_work_fraction": ring_work / total if total else 0.0,
        }
    return out
