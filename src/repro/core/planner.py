"""Heterogeneous parallelism planner — solve for the per-wave config.

``dp_balance`` costs a *fixed* configuration: one global context-parallel
degree, one chunk size, one K, applied to every lockstep wave. The paper's
long-tail length distribution makes any single choice wrong for most waves:
the 256K-token tail group wants its tokens sharded over a wide "seq" ring
(per-device K/V and per-tick compute both scale 1/cp), while the packed
short chunks that dominate the batch by count are ring-ineligible — ppermute
latency and the per-tick launch overhead never amortize, and a ring wave
only has ``data`` slots where a cp=1 wave can pack ``data * seq`` units in
parallel on the very same devices (FlexSP's per-bucket group solving).

This module turns that observation into a solver:

  * :func:`solve_waves` partitions a batch's WorkUnits into lockstep waves
    and picks, **per wave**, whether it rides the "seq" ring (cp = mesh seq
    size, ``data`` slots) or packs cp=1 units over the whole device block
    (``data * seq`` slots). The split is chosen globally across the whole
    batch — Skrull-style scheduling over all waves, not greedily within one
    — by exact subset enumeration on small instances and a sorted-prefix
    scan (which always contains the all-ring / all-packed fixed configs)
    at scale.
  * :func:`wave_cost` is the closed-form score: static-shape tick cost
    (every tick computes the full capacity-padded ChunkSize slot — masked
    slots burn FLOPs) through ``schedule_sim.simulate_rotation``, plus an
    explicit ring-communication term built on ``dp_balance.ring_step_count``.
    Everything is host integer/float math: the solver is CI-testable with
    no devices, and the executors report the matching schedule accounting.
  * :class:`ExecutionPlan` is the single product all three executors
    consume (``chunked_step.run_batch``, ``distributed.pipeline
    .run_batch_pipelined``, ``distributed.context_parallel.run_batch_cp``):
    mesh shape, per-wave cp groups, chunk-slot assignments, K, ChunkSize.
    Waves whose plan says cp=1 are routed to the replicated/packed path and
    never pay ring hops.

``plan_batch`` is the front door; ``policy="solve"`` gives the
heterogeneous plan, ``policy="lpt"``/``"round_robin"`` reproduce the
pre-planner behavior exactly (global cp + ``cp_threshold`` gating through
``dp_balance.plan_assignment``/``wave_schedule``) for the deprecation shim.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Optional

import numpy as np

from repro.core import dp_balance
from repro.core.dp_balance import prefix_capacity, ring_step_count
from repro.core.schedule_sim import simulate_rotation

# ------------------------------------------------------------ cost model ----
# Per-tick under-saturation overhead in token units — same constant family as
# tuning.seq_time: a rotation/wave tick pays kernel-launch + dispatch cost
# that does NOT shrink when the ring divides the tokens. This term is what
# makes short chunks ring-ineligible.
TICK_OVERHEAD = 2000.0
# Ring cost constants live in dp_balance (ONE home — the wave packer and
# this solver must price a hop identically; tests pin the agreement) and are
# re-exported here for existing callers.
RING_LATENCY = dp_balance.RING_LATENCY
RING_BW = dp_balance.RING_BW

# Exact-solve bound: at or below this many units the solver enumerates every
# ring/packed subset (2^n scored partitions); above it, the sorted-prefix
# scan. tests/test_planner.py pins solver == brute force inside this bound.
EXACT_UNITS = 12


def tick_cost(n_chunks: int, chunk_size: int, cp: int = 1, *,
              horizon: float = dp_balance.ATTN_HORIZON,
              overhead: float = TICK_OVERHEAD) -> float:
    """Cost of ONE lockstep chunk tick of a wave whose longest unit spans
    ``n_chunks`` chunks, in token units.

    Static-shape semantics (what the executors actually run): every tick
    computes a full ChunkSize slot against the capacity-padded StateStore
    prefix — ``prefix_capacity(n, C)`` keys, masked slots burn FLOPs — so
    the cost depends only on (n_chunks, chunk_size, cp), never on
    tokens_used. Compute divides by cp (the ring shards tokens); the
    per-tick overhead does not.
    """
    cap = prefix_capacity(n_chunks, chunk_size)
    quad = chunk_size * (cap + chunk_size) / horizon
    return (chunk_size + quad) / cp + overhead


def ring_comm_cost(n_chunks: int, chunk_size: int, cp: int,
                   k: int = 1, *, overlap: bool = False) -> float:
    """Communication cost of running one ring unit through Algorithm 2:
    ``ring_step_count`` ppermute hops (the executors' ``stats.ring_steps``
    with n_layers=1), each paying fixed latency + the bandwidth cost of the
    circulating (cap + C)/cp K/V shard. The serial formula is canonical in
    ``dp_balance.ring_comm_cost``; this delegates to it.

    With ``overlap=True`` (the double-buffered ring the executors run by
    default) the ``dp_balance.overlapped_ring_hops`` K/V prefetch hops hide
    under the hop's flash kernel and only pay their EXPOSED remainder
    ``max(0, comm_per_hop - per_hop_kernel)``; the dk/dv accumulator's final
    hops home stay fully exposed."""
    if cp <= 1:
        return 0.0
    serial = dp_balance.ring_comm_cost(n_chunks, chunk_size, cp, k=k)
    if not overlap:
        return serial
    n = n_chunks
    rec = max(n - max(1, k), 0)
    total = ring_step_count(n, cp, k=k)
    hidden = dp_balance.overlapped_ring_hops(n + rec, n, cp)
    exposed = total - hidden
    comm_per_hop = serial / total
    # One tick's kernel spans cp ring hops, so a single hop can hide under
    # ~1/cp of the tick's compute (overhead excluded: launch cost does not
    # shrink and is not a hiding window).
    per_hop_kernel = tick_cost(n, chunk_size, cp, overhead=0.0) / cp
    return (hidden * max(0.0, comm_per_hop - per_hop_kernel)
            + exposed * comm_per_hop)


def wave_cost(n_chunks: int, chunk_size: int, k: int, cp: int,
              pp: int = 1, *, overlap: bool = False) -> float:
    """Closed-form cost of one lockstep wave: the Algorithm-2 schedule of
    its padded ``n_chunks`` slot stream (every slot F + 2x B, first N-K
    recomputed), at the static-shape tick cost, run through the rotation
    pipeline when pp > 1 (``simulate_rotation`` — at pp == 1 this reduces
    to exactly (3N + recompute) ticks), plus the ring-communication term
    (overlap-discounted when ``overlap=True``; see ``ring_comm_cost``).
    """
    if n_chunks <= 0:
        return 0.0
    unit = tick_cost(n_chunks, chunk_size, cp)
    sched = simulate_rotation([n_chunks], max(pp, 1), k, unit=unit).makespan
    return sched + ring_comm_cost(n_chunks, chunk_size, cp, k=k,
                                  overlap=overlap)


# ------------------------------------------------------------------ plan ----
@dataclasses.dataclass(frozen=True)
class WavePlan:
    """One lockstep wave of the plan.

    cp:    "seq"-ring degree every slot of this wave runs at. 1 means the
           wave packs cp=1 units over the whole device block (data * seq
           slots, no ring hops); > 1 means each slot's tokens shard over a
           cp-wide "seq" ring (data slots).
    slots: tuple[Optional[WorkUnit]] of length = wave width; None slots are
           idle ranks padded with dummy all-masked chunks by the executor.
    """
    cp: int
    slots: tuple

    @property
    def width(self) -> int:
        return len(self.slots)

    @property
    def n_chunks(self) -> int:
        """Lockstep slot count: every unit is padded to the wave's longest."""
        return max((u.n_chunks for u in self.slots if u is not None),
                   default=0)

    def __repr__(self):
        live = sum(u is not None for u in self.slots)
        return (f"WavePlan(cp={self.cp}, width={self.width}, "
                f"units={live}, n_chunks={self.n_chunks})")


@dataclasses.dataclass
class ExecutionPlan:
    """The solved launch configuration all three executors consume.

    Mesh shape (data x pipe x seq), the per-wave cp groups with their
    chunk-slot assignments (``waves``), and the Algorithm-2 knobs
    (``k``, ``chunk_size``, ``blockwise_threshold``). Build with
    :func:`plan_batch` (or the executors' deprecation shim builds one from
    the old kwargs). ``mesh`` is the live jax mesh when the plan is meant
    to execute; shape-only plans (benchmarks, tuning) leave it None.
    """
    data: int
    pipe: int
    seq: int
    chunk_size: int
    k: int
    waves: list                      # list[WavePlan]
    policy: str = "solve"
    blockwise_threshold: int = 8192
    predicted_makespan: float = 0.0
    mesh: Any = None
    # Ring-overlap depth: True double-buffers the cp ring (hop i+1's
    # ppermute issued under hop i's kernel — numerically identical, comm
    # mostly hidden); False runs the serial ring (debug / A-B timing).
    ring_overlap: bool = True
    # Host-offloaded StateStore: cold prefix capacity buckets live in pinned
    # host memory and stream back on the planner's prefetch schedule
    # (`prefix_access_order`), bounding the device-resident set to the
    # latest version + K vjp-captured versions + the prefetch window.
    offload_statestore: bool = False
    prefetch_depth: int = 2

    @property
    def mesh_shape(self) -> dict:
        return {"data": self.data, "pipe": self.pipe, "seq": self.seq}

    @property
    def world_size(self) -> int:
        return self.data * self.pipe * self.seq

    @property
    def wave_cps(self) -> list:
        return [w.cp for w in self.waves]

    @property
    def heterogeneous(self) -> bool:
        return len(set(self.wave_cps)) > 1

    def describe(self) -> str:
        rings = sum(1 for w in self.waves if w.cp > 1)
        return (f"ExecutionPlan[{self.policy}] mesh=(data={self.data}, "
                f"pipe={self.pipe}, seq={self.seq}) C={self.chunk_size} "
                f"K={self.k} waves={len(self.waves)} "
                f"(ring={rings}, packed={len(self.waves) - rings}) "
                f"makespan={self.predicted_makespan:.0f}")


def plan_makespan(waves, chunk_size: int, k: int, pp: int = 1, *,
                  overlap: bool = False) -> float:
    """Total simulated makespan of a wave list — the additive lockstep sum
    the executors realize (waves run back to back on the whole mesh)."""
    return sum(wave_cost(w.n_chunks, chunk_size, k, w.cp, pp=pp,
                         overlap=overlap)
               for w in waves)


# ------------------------------------------------- StateStore offload -------
def prefix_access_order(n_chunks: int, k: int) -> list:
    """The exact order Algorithm 2 reads StateStore prefix versions: chunk i
    reads version i at its F event (ascending), then the recomputed F2
    events re-read versions keep_from-1 .. 0 (descending). This is the
    per-WavePlan prefetch schedule the host-offloaded store consumes —
    `tests/test_statestore.py` pins it equal to the order `run_group`
    derives from `alg2_schedule` itself."""
    n = n_chunks
    keep_from = max(n - max(1, k), 0)
    return list(range(n)) + list(reversed(range(keep_from)))


def statestore_device_bytes(n_chunks: int, chunk_size: int, cp: int = 1, *,
                            n_layers: int = 1, bytes_per_token: float = 1.0,
                            k: int = 1, offload: bool = False,
                            prefetch_depth: int = 2) -> float:
    """Peak per-device resident StateStore K/V bytes for one ring unit.

    Without offload every written prefix version stays device-resident until
    the group's backward completes (retained chunks' vjp closures capture
    their input version; the executor's version list pins the rest), so
    residency is (n_chunks + 1) capacity buffers. With offload the device
    store is bounded by the latest version, the K vjp-captured retained
    versions, one in-flight write, plus the ``prefetch_depth`` C-slot
    host->device streaming window — independent of sequence length's
    version count.
    """
    cap = prefix_capacity(n_chunks, chunk_size)
    shard = cap * n_layers * bytes_per_token / cp
    if not offload:
        return (n_chunks + 1) * shard
    window = (prefetch_depth * chunk_size * n_layers * bytes_per_token) / cp
    return (max(1, k) + 2) * shard + window


# ---------------------------------------------------------------- solver ----
def _unit_order(units) -> list:
    """Deterministic largest-first order: waves cost the max of their slots,
    so grouping sorted neighbors minimizes the sum of per-wave maxima."""
    return sorted(units, key=lambda u: (-u.n_chunks, -u.work, u.kind,
                                        str(u.key)))


def _pack(ordered, width: int, cp: int) -> list:
    """Group an ordered unit list into width-slot waves (last wave padded
    with None slots)."""
    waves = []
    for i in range(0, len(ordered), width):
        block = ordered[i:i + width]
        slots = tuple(block) + (None,) * (width - len(block))
        waves.append(WavePlan(cp=cp, slots=slots))
    return waves


def _score_split(ring_units, packed_units, *, data: int, seq: int,
                 chunk_size: int, k: int, pp: int):
    waves = (_pack(ring_units, data, seq) +
             _pack(packed_units, data * seq, 1))
    return waves, plan_makespan(waves, chunk_size, k, pp=pp)


def solve_waves(units, *, data: int, seq: int, pp: int = 1, k: int = 1,
                chunk_size: int, exact_limit: int = EXACT_UNITS):
    """Solve the per-wave (cp, grouping) assignment for one batch.

    Returns (waves, makespan). Ring waves run cp=seq over ``data`` slots;
    packed waves run cp=1 over ``data * seq`` slots. With ``len(units) <=
    exact_limit`` every ring/packed subset is scored (exact); above that, a
    sorted-prefix scan — the longest i units ride the ring — which by
    construction contains both fixed extremes (i=0: pure cp=1, i=n: pure
    cp=seq), so the solved plan is never worse than either fixed config.
    """
    ordered = _unit_order(units)
    n = len(ordered)
    if seq <= 1 or n == 0:
        return _score_split(ordered, [], data=data, seq=1,
                            chunk_size=chunk_size, k=k, pp=pp)

    best = None
    if n <= exact_limit:
        splits = ((tuple(u for j, u in enumerate(ordered) if mask >> j & 1),
                   tuple(u for j, u in enumerate(ordered)
                         if not mask >> j & 1))
                  for mask in range(1 << n))
    else:
        splits = ((tuple(ordered[:i]), tuple(ordered[i:]))
                  for i in range(n + 1))
    for ring, packed in splits:
        waves, m = _score_split(list(ring), list(packed), data=data, seq=seq,
                                chunk_size=chunk_size, k=k, pp=pp)
        if best is None or m < best[1] - 1e-9:
            best = (waves, m)
    return best


def fixed_waves(units, *, world: int, cp: int, pp: int = 1, k: int = 1,
                chunk_size: int):
    """Score a FIXED (cp, C, K) config — every wave at the same cp, width
    world // cp — the single-config baseline the solver must beat.
    Returns (waves, makespan)."""
    assert world % cp == 0, (world, cp)
    ordered = _unit_order(units)
    if cp > 1:
        waves = _pack(ordered, world // cp, cp)
    else:
        waves = _pack(ordered, world, 1)
    return waves, plan_makespan(waves, chunk_size, k, pp=pp)


# ----------------------------------------------------------- plan_batch -----
def _mesh_shape(mesh) -> tuple:
    """-> (data, pipe, seq) for a jax mesh / shape dict / None. Duck-typed
    so this module never imports jax (the solver is pure host math)."""
    if mesh is None:
        return 1, 1, 1
    if isinstance(mesh, dict):
        return (int(mesh.get("data", 1)), int(mesh.get("pipe", 1)),
                int(mesh.get("seq", 1)))
    sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    data = int(sizes.get("pod", 1)) * int(sizes.get("data", 1))
    return data, int(sizes.get("pipe", 1)), int(sizes.get("seq", 1))


def _legacy_waves(units, *, data: int, seq: int, policy: str,
                  cp_threshold: int):
    """The pre-planner wave former, bit-for-bit: dp_balance LPT/round-robin
    rank streams -> lockstep waves of width ``data``; a wave rides the ring
    at cp=seq iff any of its units is ring-eligible (global cp +
    cp_threshold gating), and cp=1 waves replicate over "seq" (width stays
    ``data``, NOT data*seq — exactly what the old executors did)."""
    plan = dp_balance.plan_assignment(units, data, policy=policy)
    waves, _ = dp_balance.wave_schedule(plan)
    out = []
    for wave in waves:
        ring = seq > 1 and any(u is not None and u.ring for u in wave)
        out.append(WavePlan(cp=seq if ring else 1, slots=tuple(wave)))
    return out


def plan_batch(groups, standalone, mesh=None, *, k: int = 1,
               policy: str = "solve", cp_threshold: int = 0,
               blockwise_threshold: int = 8192,
               horizon: float = dp_balance.ATTN_HORIZON,
               ring_overlap: bool = True, offload_statestore: bool = False,
               prefetch_depth: int = 2) -> ExecutionPlan:
    """Solve (or legacy-form) the ExecutionPlan for one materialized batch.

    groups / standalone: `launch.train.build_host_batches` output — the
    payloads ride into the plan's WorkUnits, so the executors can stack the
    planned waves directly.
    mesh: jax mesh, {"data","pipe","seq"} shape dict, or None (single
    device). policy: "solve" = heterogeneous per-wave cp solver; "lpt" /
    "round_robin" = the pre-planner global-cp former (used by the
    deprecation shim; honors ``cp_threshold``).
    """
    data, pipe, seq = _mesh_shape(mesh)
    chunk_size = 0
    if groups:
        chunk_size = int(np.asarray(groups[0][0]["segment_ids"]).shape[1])
    elif standalone:
        chunk_size = int(np.asarray(standalone[0]["segment_ids"]).shape[1])

    if policy in ("lpt", "round_robin"):
        units = dp_balance.units_from_materialized(
            groups, standalone, k=k, horizon=horizon, static_shapes=True,
            cp=seq, cp_threshold=cp_threshold)
        waves = _legacy_waves(units, data=data, seq=seq, policy=policy,
                              cp_threshold=cp_threshold)
    elif policy == "solve":
        units = dp_balance.units_from_materialized(
            groups, standalone, k=k, horizon=horizon, static_shapes=True)
        waves, _ = solve_waves(units, data=data, seq=seq, pp=pipe, k=k,
                               chunk_size=chunk_size)
    else:
        raise ValueError(f"unknown plan policy {policy!r} "
                         "(want 'solve', 'lpt' or 'round_robin')")

    return ExecutionPlan(
        data=data, pipe=pipe, seq=seq, chunk_size=chunk_size, k=k,
        waves=waves, policy=policy, blockwise_threshold=blockwise_threshold,
        predicted_makespan=plan_makespan(waves, chunk_size, k, pp=pipe,
                                         overlap=ring_overlap),
        mesh=mesh if not isinstance(mesh, dict) else None,
        ring_overlap=ring_overlap, offload_statestore=offload_statestore,
        prefetch_depth=prefetch_depth)


def plan_lengths(lengths: dict, chunk_size: int, mesh=None, *, k: int = 1,
                 policy: str = "solve", **kw) -> ExecutionPlan:
    """Shape-only planning from raw sequence lengths (no materialization):
    Algorithm 1 chunking -> WorkUnits -> plan. Payloads are the Chunk
    metadata, so the plan scores/simulates but does not execute — the
    tuner and benchmarks use this."""
    from repro.core.chunking import construct_chunks, group_chunks
    g, s = group_chunks(construct_chunks(lengths, chunk_size))
    data, pipe, seq = _mesh_shape(mesh)
    units = dp_balance.units_from_chunks(g, s, k=k, static_shapes=True)
    if policy == "solve":
        waves, _ = solve_waves(units, data=data, seq=seq, pp=pipe, k=k,
                               chunk_size=chunk_size)
    else:
        units = dp_balance.units_from_chunks(
            g, s, k=k, static_shapes=True, cp=seq,
            cp_threshold=kw.get("cp_threshold", 0))
        waves = _legacy_waves(units, data=data, seq=seq, policy=policy,
                              cp_threshold=kw.get("cp_threshold", 0))
    return ExecutionPlan(
        data=data, pipe=pipe, seq=seq, chunk_size=chunk_size, k=k,
        waves=waves, policy=policy,
        predicted_makespan=plan_makespan(waves, chunk_size, k, pp=pipe))


def solve_world(units, *, world: int, pp: int = 1, k: int = 1,
                chunk_size: int, seqs=None):
    """Search mesh factorizations too: for each (data, seq) with
    data * seq == world // pp, solve the heterogeneous wave split; return
    (best_waves, best_makespan, (data, seq)). ``seqs`` restricts the
    candidate seq sizes (default: every divisor)."""
    slots = world // max(pp, 1)
    cands = [s for s in (seqs or _divisors(slots))]
    best = None
    for seq in cands:
        if slots % seq:
            continue
        waves, m = solve_waves(units, data=slots // seq, seq=seq, pp=pp,
                               k=k, chunk_size=chunk_size)
        if best is None or m < best[1] - 1e-9:
            best = (waves, m, (slots // seq, seq))
    return best


def _divisors(n: int) -> list:
    return [d for d in range(1, n + 1) if n % d == 0]
