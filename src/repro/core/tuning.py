"""ChunkSize / K grid search (paper §5).

"For a given training configuration, we leverage a grid search method for
ChunkSize and K and select the best combination for optimal performance."

Without pipeline parallelism the paper's rule is closed-form: K=1 and the
largest ChunkSize that fits memory. With PP, each candidate is scored on
batches sampled from the actual length distribution (more chunks = fewer
bubbles, bigger chunks = better per-token efficiency), subject to the
K*ChunkSize activation-memory budget — using ``schedule_sim
.simulate_rotation``, the closed form of the rotation schedule the PR-4
executor (``distributed.pipeline.run_batch_pipelined``) actually runs.
Scoring with ``simulate_1f1b`` (the pre-PR-4 behavior) models Megatron's
per-rank variable-duration schedule instead: short chunks cost less than a
tick there, while the rotation executes every capacity-padded slot as one
uniform tick — so 1F1B scores could rank candidates differently from the
measured makespan (tests/test_tuning.py pins the fix).
"""
from __future__ import annotations

import dataclasses

from repro.core.chunking import construct_chunks, group_chunks
from repro.core.schedule_sim import chunks_to_microbatches, simulate_rotation


@dataclasses.dataclass(frozen=True)
class TuneResult:
    chunk_size: int
    k: int
    score: float                 # mean simulated makespan (lower = better)
    table: dict                  # (chunk_size, k) -> score


def seq_time(tokens, overhead=2000.0):
    """Per-micro-step cost: linear + under-saturation overhead. (No
    quadratic attention term here: a long sequence's total attention cost is
    chunk-size-invariant — intra-chunk quadratic + prefix reads sum to the
    same triangle — so it cancels out of the ChunkSize comparison.)"""
    return tokens + overhead


def rotation_wave_sizes(chunks) -> list:
    """Chunk count of each lockstep wave the rotation executor would run for
    this batch at dp=1: one wave per dependent group plus one single-chunk
    wave per packed standalone chunk (`dp_balance.wave_schedule` with
    world_size=1 — every unit is its own wave, and wave order does not
    change the additive makespan)."""
    groups, standalone = group_chunks(chunks)
    return [len(g) for g in groups.values()] + [1] * len(standalone)


def grid_search(batches, *, pp: int, memory_token_budget: int,
                chunk_sizes=(2048, 4096, 8192, 16384, 32768),
                ks=(1, 2, 4, 8, 16)):
    """batches: list of {seq_id: length} dicts sampled from the real data
    distribution. memory_token_budget: max K*ChunkSize live activation
    tokens. Returns TuneResult; K is forced to 1 when pp == 1 (paper §5).

    pp > 1 candidates are scored in ``simulate_rotation`` units — every
    rotation tick processes one capacity-padded ChunkSize slot, costed at
    ``seq_time(chunk_size)`` — matching `PipelineStats.makespan_units` from
    the real executor tick for tick."""
    if pp == 1:
        ks = (1,)
    table = {}
    for cs in chunk_sizes:
        for k in ks:
            if k * cs > memory_token_budget:
                continue
            total = 0.0
            for lengths in batches:
                chunks = construct_chunks(lengths, cs)
                if pp == 1:
                    mbs = chunks_to_microbatches(chunks, k=k)
                    mbs = [dataclasses.replace(m, fwd=seq_time(m.fwd))
                           for m in mbs]
                    total += sum(3.0 * m.fwd + (m.fwd if m.recompute else 0.0)
                                 for m in mbs)
                else:
                    total += simulate_rotation(
                        rotation_wave_sizes(chunks), pp, k,
                        unit=seq_time(cs)).makespan
            table[(cs, k)] = total / len(batches)
    best = min(table, key=table.get)
    return TuneResult(chunk_size=best[0], k=best[1], score=table[best],
                      table=table)
