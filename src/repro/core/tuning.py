"""ChunkSize / K grid search (paper §5).

"For a given training configuration, we leverage a grid search method for
ChunkSize and K and select the best combination for optimal performance."

Without pipeline parallelism the paper's rule is closed-form: K=1 and the
largest ChunkSize that fits memory. With PP, each candidate is scored on
batches sampled from the actual length distribution (more chunks = fewer
bubbles, bigger chunks = better per-token efficiency), subject to the
K*ChunkSize activation-memory budget — using ``schedule_sim
.simulate_rotation``, the closed form of the rotation schedule the PR-4
executor (``distributed.pipeline.run_batch_pipelined``) actually runs.
Scoring with ``simulate_1f1b`` (the pre-PR-4 behavior) models Megatron's
per-rank variable-duration schedule instead: short chunks cost less than a
tick there, while the rotation executes every capacity-padded slot as one
uniform tick — so 1F1B scores could rank candidates differently from the
measured makespan (tests/test_tuning.py pins the fix).
"""
from __future__ import annotations

import dataclasses

from repro.core.chunking import construct_chunks, group_chunks
from repro.core.schedule_sim import chunks_to_microbatches, simulate_rotation


@dataclasses.dataclass(frozen=True)
class LaunchConfig:
    """One ranked launch candidate from `grid_search`: a complete config
    (mesh factorization + Algorithm-2 knobs) with its predicted cost.
    ``heterogeneous`` marks the planner-solved per-wave-cp entry (scored by
    `planner.solve_world`) rather than a fixed global cp."""
    dp: int
    pp: int
    cp: int
    chunk_size: int
    k: int
    makespan: float              # mean simulated makespan (lower = better)
    memory_tokens: int           # K*C live residuals + per-device KV slots
    heterogeneous: bool = False

    def describe(self) -> str:
        kind = "solve" if self.heterogeneous else "fixed"
        return (f"dp={self.dp} pp={self.pp} cp={self.cp} "
                f"C={self.chunk_size} K={self.k} [{kind}] "
                f"makespan={self.makespan:.0f} mem={self.memory_tokens}")


@dataclasses.dataclass(frozen=True)
class TuneResult:
    chunk_size: int
    k: int
    score: float                 # mean simulated makespan (lower = better)
    table: dict                  # (chunk_size, k[, cp]) -> score
    ranked: tuple = ()           # LaunchConfigs, best (lowest makespan) first


def seq_time(tokens, overhead=2000.0):
    """Per-micro-step cost: linear + under-saturation overhead. (No
    quadratic attention term here: a long sequence's total attention cost is
    chunk-size-invariant — intra-chunk quadratic + prefix reads sum to the
    same triangle — so it cancels out of the ChunkSize comparison.)"""
    return tokens + overhead


def rotation_wave_sizes(chunks) -> list:
    """Chunk count of each lockstep wave the rotation executor would run for
    this batch at dp=1: one wave per dependent group plus one single-chunk
    wave per packed standalone chunk (`dp_balance.wave_schedule` with
    world_size=1 — every unit is its own wave, and wave order does not
    change the additive makespan)."""
    groups, standalone = group_chunks(chunks)
    return [len(g) for g in groups.values()] + [1] * len(standalone)


def grid_search(batches, *, pp: int, memory_token_budget: int,
                chunk_sizes=(2048, 4096, 8192, 16384, 32768),
                ks=(1, 2, 4, 8, 16), world_size: int = None, cps=None,
                include_heterogeneous: bool = False):
    """batches: list of {seq_id: length} dicts sampled from the real data
    distribution. memory_token_budget: max K*ChunkSize live activation
    tokens. Returns TuneResult; K is forced to 1 when pp == 1 (paper §5).

    pp > 1 candidates are scored in ``simulate_rotation`` units — every
    rotation tick processes one capacity-padded ChunkSize slot, costed at
    ``seq_time(chunk_size)`` — matching `PipelineStats.makespan_units` from
    the real executor tick for tick.

    ``world_size`` switches to WORLD mode: candidates become full launch
    configs over a world_size-device (data x pipe x seq) mesh. Each
    (chunk_size, K, cp) is scored with `planner.fixed_waves` (the lockstep
    wave makespan the executors realize, ring comm included) averaged over
    the batches, with table keys (chunk_size, k, cp); ``cps`` restricts the
    candidate cp degrees (default: every divisor of world_size // pp).
    ``include_heterogeneous`` additionally scores, per (chunk_size, K), the
    planner-SOLVED per-wave-cp plan over every mesh factorization
    (`planner.solve_world`) and ranks it alongside — these appear only in
    ``ranked`` (flagged ``heterogeneous``), not in the fixed-config table.
    K is not forced to 1 here: waves of dependent chunks pass through the
    Algorithm-2 recompute schedule where K > 1 trades memory for F2 ticks
    even without pipelining. ``ranked`` lists every candidate best-first."""
    if world_size is not None:
        return _grid_search_world(
            batches, pp=pp, memory_token_budget=memory_token_budget,
            chunk_sizes=chunk_sizes, ks=ks, world_size=world_size, cps=cps,
            include_heterogeneous=include_heterogeneous)
    if pp == 1:
        ks = (1,)
    table = {}
    for cs in chunk_sizes:
        for k in ks:
            if k * cs > memory_token_budget:
                continue
            total = 0.0
            for lengths in batches:
                chunks = construct_chunks(lengths, cs)
                if pp == 1:
                    mbs = chunks_to_microbatches(chunks, k=k)
                    mbs = [dataclasses.replace(m, fwd=seq_time(m.fwd))
                           for m in mbs]
                    total += sum(3.0 * m.fwd + (m.fwd if m.recompute else 0.0)
                                 for m in mbs)
                else:
                    total += simulate_rotation(
                        rotation_wave_sizes(chunks), pp, k,
                        unit=seq_time(cs)).makespan
            table[(cs, k)] = total / len(batches)
    best = min(table, key=table.get)
    ranked = tuple(sorted(
        (LaunchConfig(dp=1, pp=pp, cp=1, chunk_size=cs, k=k,
                      makespan=score, memory_tokens=k * cs)
         for (cs, k), score in table.items()),
        key=lambda c: (c.makespan, c.chunk_size, c.k)))
    return TuneResult(chunk_size=best[0], k=best[1], score=table[best],
                      table=table, ranked=ranked)


def _grid_search_world(batches, *, pp: int, memory_token_budget: int,
                       chunk_sizes, ks, world_size: int, cps,
                       include_heterogeneous: bool):
    """World-mode grid search body — see `grid_search`."""
    from repro.core import dp_balance, planner

    slots = world_size // max(pp, 1)
    if cps is None:
        cps = tuple(d for d in range(1, slots + 1) if slots % d == 0)
    table, ranked = {}, []
    for cs in chunk_sizes:
        for k in ks:
            if k * cs > memory_token_budget:
                continue
            batch_units = []
            for lengths in batches:
                g, s = group_chunks(construct_chunks(lengths, cs))
                batch_units.append(dp_balance.units_from_chunks(
                    g, s, k=k, static_shapes=True))
            # per-device StateStore KV slots of the longest unit (its cap
            # divides by cp on the ring) + the K*C live residual bound
            cap_max = max((dp_balance.prefix_capacity(u.n_chunks, cs)
                           for units in batch_units for u in units),
                          default=0)
            for cp in cps:
                total = sum(planner.fixed_waves(
                    units, world=slots, cp=cp, pp=pp, k=k, chunk_size=cs)[1]
                    for units in batch_units)
                score = total / len(batches)
                table[(cs, k, cp)] = score
                ranked.append(LaunchConfig(
                    dp=slots // cp, pp=pp, cp=cp, chunk_size=cs, k=k,
                    makespan=score,
                    memory_tokens=k * cs + cap_max // cp))
            if include_heterogeneous:
                total, shape = 0.0, (slots, 1)
                for units in batch_units:
                    _, m, shape = planner.solve_world(
                        units, world=world_size, pp=pp, k=k, chunk_size=cs)
                    total += m
                ranked.append(LaunchConfig(
                    dp=shape[0], pp=pp, cp=shape[1], chunk_size=cs, k=k,
                    makespan=total / len(batches),
                    memory_tokens=k * cs + cap_max // max(shape[1], 1),
                    heterogeneous=True))
    ranked = tuple(sorted(
        ranked, key=lambda c: (c.makespan, c.chunk_size, c.k, c.cp,
                               c.heterogeneous)))
    best = ranked[0]
    return TuneResult(chunk_size=best.chunk_size, k=best.k,
                      score=best.makespan, table=table, ranked=ranked)
