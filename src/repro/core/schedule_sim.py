"""Analytic pipeline-schedule simulator (paper Figs. 2, 6, 7).

Models 1F1B pipeline execution with variable-duration microbatches under the
paper's assumptions: backward = 2x forward; execution time proportional to
sequence length. The *state-aware* variant adds ChunkFlow semantics:
dependent-group backwards run in reverse chunk order, and the first N-K
chunks of each group pay a recompute forward immediately before their
backward (Algorithm 2 at pipeline scale).

Timing uses a static per-stage op order (the 1F1B interleave) + dependency-
respecting earliest-start scheduling, which is exactly how Megatron executes.

Bubble accounting: bubble ratio = total idle time / (stages * makespan).
Recompute time is counted as *bubble* (it is not useful work), matching the
paper's Fig. 6 numbers — see tests/test_schedule_sim.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Microbatch:
    fwd: float
    group: Optional[int] = None    # dependent-group id
    index_in_group: int = 0
    group_size: int = 1
    recompute: bool = False        # pays an extra fwd before backward

    @property
    def bwd(self) -> float:
        return 2.0 * self.fwd


@dataclasses.dataclass
class SimResult:
    makespan: float
    useful_time: float             # summed F+B across stages
    recompute_time: float
    bubble_ratio: float
    per_stage_timeline: list       # [(stage, op, mb, start, end)]


def _backward_order(mbs):
    """FIFO, but each dependent group's backwards reversed (state-aware)."""
    order = []
    emitted = set()
    for j, mb in enumerate(mbs):
        if j in emitted:
            continue
        if mb.group is None:
            order.append(j)
            emitted.add(j)
        else:
            members = [i for i, m in enumerate(mbs) if m.group == mb.group]
            members.sort(key=lambda i: mbs[i].index_in_group, reverse=True)
            order.extend(members)
            emitted.update(members)
    return order


def simulate_1f1b(mbs, n_stages: int, *, state_aware: bool = False):
    """Discrete-event 1F1B simulation. mbs: list[Microbatch] in arrival order.

    Per-stage dispatch policy (Megatron 1F1B): keep at most ``P - s``
    microbatches in flight; prefer backwards once at the limit. Backwards are
    emitted strictly in ``b_order`` (FIFO, or group-reversed when
    state_aware) — head-of-line blocking models the KV-gradient dependency.
    """
    M, P = len(mbs), n_stages
    b_order = _backward_order(mbs) if state_aware else list(range(M))

    f_end = [[None] * M for _ in range(P)]
    b_end = [[None] * M for _ in range(P)]
    f_next = [0] * P                  # next forward index to emit per stage
    b_next = [0] * P                  # pointer into b_order per stage
    stage_free = [0.0] * P
    timeline = []
    recompute_time = 0.0
    done = 0

    def ready_f(s, t):
        j = f_next[s]
        if j >= M:
            return None
        dep = 0.0 if s == 0 else f_end[s - 1][j]
        if dep is None or dep > t + 1e-12:
            return None
        return j

    def ready_b(s, t):
        if b_next[s] >= M:
            return None
        j = b_order[b_next[s]]
        if f_end[s][j] is None or f_end[s][j] > t + 1e-12:
            return None
        dep = f_end[s][j] if s == P - 1 else b_end[s + 1][j]
        if dep is None or dep > t + 1e-12:
            return None
        return j

    # event times to (re)try dispatching
    times = {0.0}
    while done < 2 * M * P:
        t = min(times)
        times.discard(t)
        progressed = False
        for s in range(P):
            while stage_free[s] <= t + 1e-12:
                in_flight = f_next[s] - b_next[s]
                limit = min(M, P - s)
                fj, bj = ready_f(s, t), ready_b(s, t)
                if bj is not None and (in_flight >= limit or fj is None):
                    kind, j = "B", bj
                elif fj is not None:
                    kind, j = "F", fj
                elif bj is not None:
                    kind, j = "B", bj
                else:
                    break
                mb = mbs[j]
                start = max(stage_free[s], t)
                if kind == "F":
                    end = start + mb.fwd
                    f_end[s][j] = end
                    f_next[s] += 1
                else:
                    extra = mb.fwd if (state_aware and mb.recompute) else 0.0
                    end = start + extra + mb.bwd
                    recompute_time += extra
                    b_end[s][j] = end
                    b_next[s] += 1
                timeline.append((s, kind, j, start, end))
                stage_free[s] = end
                times.add(end)
                done += 1
                progressed = True
        if not times and done < 2 * M * P:
            raise RuntimeError("deadlocked schedule")

    makespan = max(stage_free)
    useful = sum(mb.fwd + mb.bwd for mb in mbs) * P
    bubble = P * makespan - useful            # recompute counted as bubble
    return SimResult(
        makespan=makespan,
        useful_time=useful,
        recompute_time=recompute_time,
        bubble_ratio=bubble / (P * makespan),
        per_stage_timeline=timeline,
    )


# --------------------------------------------------- ChunkFlow front-end ----
def chunks_to_microbatches(chunks, unit: float = 1.0, k: int = 1):
    """Map core.chunking.Chunk objects to simulator microbatches; mark the
    first N-K chunks of each dependent group for recompute (Alg. 2)."""
    mbs = []
    for c in chunks:
        rec = (c.dependent and c.index_in_group < max(c.group_size - k, 0))
        mbs.append(Microbatch(
            fwd=unit * c.tokens_used, group=c.group,
            index_in_group=c.index_in_group, group_size=c.group_size,
            recompute=rec))
    return mbs


def sequences_to_microbatches(lengths, unit: float = 1.0):
    return [Microbatch(fwd=unit * l) for l in lengths]
