"""Analytic pipeline-schedule simulator (paper Figs. 2, 6, 7).

Models 1F1B pipeline execution with variable-duration microbatches under the
paper's assumptions: backward = 2x forward; execution time proportional to
sequence length. The *state-aware* variant adds ChunkFlow semantics:
dependent-group backwards run in reverse chunk order, and the first N-K
chunks of each group pay a recompute forward immediately before their
backward (Algorithm 2 at pipeline scale).

Timing uses a static per-stage op order (the 1F1B interleave) + dependency-
respecting earliest-start scheduling, which is exactly how Megatron executes.

Bubble accounting: bubble ratio = total idle time / (stages * makespan).
Recompute time is counted as *bubble* (it is not useful work), matching the
paper's Fig. 6 numbers — see tests/test_schedule_sim.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Microbatch:
    fwd: float
    group: Optional[int] = None    # dependent-group id
    index_in_group: int = 0
    group_size: int = 1
    recompute: bool = False        # pays an extra fwd before backward

    @property
    def bwd(self) -> float:
        return 2.0 * self.fwd


@dataclasses.dataclass
class SimResult:
    makespan: float
    useful_time: float             # summed F+B across stages
    recompute_time: float
    bubble_ratio: float
    per_stage_timeline: list       # [(stage, op, mb, start, end)]


def _backward_order(mbs):
    """FIFO, but each dependent group's backwards reversed (state-aware)."""
    order = []
    emitted = set()
    for j, mb in enumerate(mbs):
        if j in emitted:
            continue
        if mb.group is None:
            order.append(j)
            emitted.add(j)
        else:
            members = [i for i, m in enumerate(mbs) if m.group == mb.group]
            members.sort(key=lambda i: mbs[i].index_in_group, reverse=True)
            order.extend(members)
            emitted.update(members)
    return order


def simulate_1f1b(mbs, n_stages: int, *, state_aware: bool = False):
    """Discrete-event 1F1B simulation. mbs: list[Microbatch] in arrival order.

    Per-stage dispatch policy (Megatron 1F1B): keep at most ``P - s``
    microbatches in flight; prefer backwards once at the limit. Backwards are
    emitted strictly in ``b_order`` (FIFO, or group-reversed when
    state_aware) — head-of-line blocking models the KV-gradient dependency.
    """
    M, P = len(mbs), n_stages
    b_order = _backward_order(mbs) if state_aware else list(range(M))

    f_end = [[None] * M for _ in range(P)]
    b_end = [[None] * M for _ in range(P)]
    f_next = [0] * P                  # next forward index to emit per stage
    b_next = [0] * P                  # pointer into b_order per stage
    stage_free = [0.0] * P
    timeline = []
    recompute_time = 0.0
    done = 0

    def ready_f(s, t):
        j = f_next[s]
        if j >= M:
            return None
        dep = 0.0 if s == 0 else f_end[s - 1][j]
        if dep is None or dep > t + 1e-12:
            return None
        return j

    def ready_b(s, t):
        if b_next[s] >= M:
            return None
        j = b_order[b_next[s]]
        if f_end[s][j] is None or f_end[s][j] > t + 1e-12:
            return None
        dep = f_end[s][j] if s == P - 1 else b_end[s + 1][j]
        if dep is None or dep > t + 1e-12:
            return None
        return j

    # event times to (re)try dispatching
    times = {0.0}
    while done < 2 * M * P:
        t = min(times)
        times.discard(t)
        progressed = False
        for s in range(P):
            while stage_free[s] <= t + 1e-12:
                in_flight = f_next[s] - b_next[s]
                limit = min(M, P - s)
                fj, bj = ready_f(s, t), ready_b(s, t)
                if bj is not None and (in_flight >= limit or fj is None):
                    kind, j = "B", bj
                elif fj is not None:
                    kind, j = "F", fj
                elif bj is not None:
                    kind, j = "B", bj
                else:
                    break
                mb = mbs[j]
                start = max(stage_free[s], t)
                if kind == "F":
                    end = start + mb.fwd
                    f_end[s][j] = end
                    f_next[s] += 1
                else:
                    extra = mb.fwd if (state_aware and mb.recompute) else 0.0
                    end = start + extra + mb.bwd
                    recompute_time += extra
                    b_end[s][j] = end
                    b_next[s] += 1
                timeline.append((s, kind, j, start, end))
                stage_free[s] = end
                times.add(end)
                done += 1
                progressed = True
        if not times and done < 2 * M * P:
            raise RuntimeError("deadlocked schedule")

    makespan = max(stage_free)
    useful = sum(mb.fwd + mb.bwd for mb in mbs) * P
    bubble = P * makespan - useful            # recompute counted as bubble
    return SimResult(
        makespan=makespan,
        useful_time=useful,
        recompute_time=recompute_time,
        bubble_ratio=bubble / (P * makespan),
        per_stage_timeline=timeline,
    )


# ----------------------------------------------- SPMD rotation schedule -----
# The executable pipeline (distributed/pipeline.py) is NOT imperative 1F1B:
# it is an SPMD *rotation* — a window of W uniform chunk microbatches flows
# through S stages in W + S - 1 lockstep ticks (every stage computes every
# tick; fill/drain ticks are masked compute, i.e. bubble). Algorithm 2 is
# applied at window granularity: the stream of a wave's N chunks is split
# into ceil(N/K) windows sized [N-(m-1)K, K, ..., K]; only the LAST window's
# forward keeps differentiation residuals (<= K chunk-states live), every
# earlier window is re-forwarded (F2) immediately before its backward.
#
# 1F1B-vs-rotation delta: `simulate_1f1b` models Megatron's per-rank
# asynchronous schedule with *variable* microbatch durations (time
# proportional to tokens_used) and head-of-line-blocking dispatch. The
# rotation executes capacity-padded C-token chunks in lockstep, so every
# tick costs one uniform unit (1 for F/F2 scans, 2 for B scans — backward =
# 2x forward, same convention as `Microbatch.bwd`) and the whole schedule is
# closed-form integer math. These helpers are that closed form; the executor
# reports the identical accounting from its real run and
# tests/test_pipeline2d.py pins executor == simulator exactly.

def rotation_windows(n_chunks: int, k: int) -> list:
    """Window sizes (front to back) of Algorithm 2 at pipeline scale: the
    last window holds exactly min(K, N) chunks (residuals kept), earlier
    windows hold K chunks each except the first, which takes the remainder —
    so recompute count is exactly N - min(K, N), matching `alg2_schedule`."""
    n, k = n_chunks, max(1, k)
    if n <= 0:
        return []
    if n <= k:
        return [n]
    m = -(-n // k)                       # ceil(n / k) windows
    return [n - (m - 1) * k] + [k] * (m - 1)


@dataclasses.dataclass
class RotationResult:
    n_stages: int
    makespan: float                # lockstep ticks, weighted (B ticks cost 2)
    useful_time: float             # F + B work summed across stages
    recompute_time: float          # F2 work summed across stages (bubble)
    bubble_ratio: float            # idle / (stages * makespan); F2 is bubble
    recompute_count: int           # chunk recomputes (== sum of N_w - K)
    peak_resident_chunks: int      # max live residual chunk-states (<= K)
    kv_capacity_slots: list        # per-wave StateStore capacity, in chunks
    scans: list = dataclasses.field(default_factory=list)  # (kind, W, ticks)


def simulate_rotation(wave_sizes, n_stages: int, k: int, *,
                      unit: float = 1.0) -> RotationResult:
    """Closed-form schedule model of the SPMD rotation executor.

    wave_sizes: chunk count of each lockstep wave (the dp_balance wave plan
    pads every rank to the wave's max, so one integer per wave suffices).
    Per window of size W: one forward scan (W+S-1 ticks x cost 1), one
    backward scan (W+S-1 ticks x cost 2), plus one recompute scan (cost 1)
    for every window except the last. Useful work is F + B only (3 units per
    chunk per stage); recompute is counted as bubble, like `simulate_1f1b`.
    """
    from repro.core.dp_balance import prefix_capacity
    S = n_stages
    makespan = 0.0
    useful = 0.0
    recompute_time = 0.0
    recompute_count = 0
    peak_resident = 0
    caps = []
    scans = []
    for n in wave_sizes:
        wins = rotation_windows(n, k)
        caps.append(prefix_capacity(n, 1))     # capacity in chunk slots
        for i, w in enumerate(wins):
            last = i == len(wins) - 1
            ticks = w + S - 1
            makespan += ticks * unit                        # forward scan
            scans.append(("F", w, ticks))
            if not last:
                makespan += ticks * unit                    # recompute scan
                recompute_time += S * w * unit
                recompute_count += w
                scans.append(("F2", w, ticks))
            makespan += 2 * ticks * unit                    # backward scan
            scans.append(("B", w, ticks))
        useful += 3.0 * n * S * unit
        peak_resident = max(peak_resident, min(max(1, k), n))
    bubble = S * makespan - useful
    return RotationResult(
        n_stages=S, makespan=makespan, useful_time=useful,
        recompute_time=recompute_time,
        bubble_ratio=bubble / (S * makespan) if makespan else 0.0,
        recompute_count=recompute_count,
        peak_resident_chunks=peak_resident,
        kv_capacity_slots=caps, scans=scans)


# --------------------------------------------------- ChunkFlow front-end ----
def chunks_to_microbatches(chunks, unit: float = 1.0, k: int = 1):
    """Map core.chunking.Chunk objects to simulator microbatches; mark the
    first N-K chunks of each dependent group for recompute (Alg. 2)."""
    mbs = []
    for c in chunks:
        rec = (c.dependent and c.index_in_group < max(c.group_size - k, 0))
        mbs.append(Microbatch(
            fwd=unit * c.tokens_used, group=c.group,
            index_in_group=c.index_in_group, group_size=c.group_size,
            recompute=rec))
    return mbs


def sequences_to_microbatches(lengths, unit: float = 1.0):
    return [Microbatch(fwd=unit * l) for l in lengths]
