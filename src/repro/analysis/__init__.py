"""chunklint: static mesh/kernel contract analysis for the ChunkFlow repo.

``python -m repro.analysis src`` walks the source tree and reports
violations of the contracts the executors rely on but nothing else checks:
mesh-axis registry discipline, ppermute cycle soundness, custom_vjp
fwd/bwd pairing, Pallas BlockSpec/grid arity, tracer hygiene, and buffer
donation safety. Stdlib-only — safe to run before jax is installed.
"""
from repro.analysis.core import (  # noqa: F401
    Baseline,
    Finding,
    ModuleCtx,
    load_axis_registry,
    run_analysis,
)
from repro.analysis.checks import ALL_CHECK_IDS  # noqa: F401
