"""chunklint core: findings, per-module AST context, baseline allowlist.

The analyzer is deliberately stdlib-only (``ast`` + ``json``): it must run in
the CI lint lane before jax is even installed, and importing jax would pull
device state into what is a pure source-level pass.

Key objects:

* ``Finding`` — one diagnostic: check ID, location, message, fix hint, and a
  *stable* suppression key (``check_id::relpath::detail``) that survives line
  churn so baseline entries don't rot on unrelated edits.
* ``ModuleCtx`` — a parsed module plus the cross-check plumbing every check
  needs: import-alias resolution (``qualname``), a parent map, and lexical
  assignment lookup (``resolve_name``) for the ``perm = [...]`` /
  ``grid = (...)`` closure idioms.
* ``Baseline`` — the allowlist, same adopt-on-``--update`` idiom as
  ``benchmarks/check_regression.py``: ``--update`` adopts current findings
  and prunes stale entries, CI fails on anything unsuppressed.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os


@dataclasses.dataclass(frozen=True)
class Finding:
    check_id: str        # e.g. "CF-AX01"
    path: str            # repo-relative posix path
    line: int
    col: int
    message: str
    hint: str = ""
    # short stable discriminator (axis literal, function name, ...) used in
    # the baseline key instead of line numbers, so suppressions survive edits
    detail: str = ""

    @property
    def key(self) -> str:
        return f"{self.check_id}::{self.path}::{self.detail or self.message}"

    def render(self) -> str:
        s = f"{self.path}:{self.line}:{self.col}: {self.check_id} {self.message}"
        if self.hint:
            s += f"\n    hint: {self.hint}"
        return s


class ModuleCtx:
    """One parsed source file + the resolution helpers checks share."""

    def __init__(self, path: str, relpath: str, source: str,
                 axes: frozenset[str] | None):
        self.path = path
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.axes = axes                     # canonical mesh axes (or None)
        self.parents: dict[ast.AST, ast.AST] = {}
        self.imports: dict[str, str] = {}    # local name -> dotted origin
        self._index()

    def _index(self):
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = (
                        f"{node.module}.{a.name}")

    # ------------------------------------------------------------ names ----
    def qualname(self, node: ast.AST) -> str:
        """Dotted name of a Name/Attribute chain with import aliases
        resolved: ``pl.pallas_call`` -> "jax.experimental.pallas.pallas_call"
        (given ``from jax.experimental import pallas as pl``). Unresolvable
        heads keep their source spelling; non-name nodes -> ""."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return ""
        head = self.imports.get(node.id, node.id)
        return ".".join([head] + list(reversed(parts)))

    def callee(self, call: ast.Call) -> str:
        """Terminal callee name: ``jax.lax.ppermute(...)`` -> "ppermute"."""
        f = call.func
        if isinstance(f, ast.Attribute):
            return f.attr
        if isinstance(f, ast.Name):
            return f.id
        return ""

    def calls(self, *names: str):
        """Every Call whose terminal callee name is in ``names``."""
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and self.callee(node) in names:
                yield node

    # ----------------------------------------------------------- scopes ----
    def enclosing_functions(self, node: ast.AST):
        """Innermost-first chain of enclosing FunctionDefs (then Module)."""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Module)):
                yield cur
            cur = self.parents.get(cur)

    def _scope_assigns(self, scope: ast.AST, name: str):
        """Assignments to ``name`` lexically inside ``scope``, skipping
        nested function bodies (those are their own scopes)."""
        out = []
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.ClassDef)):
                continue
            if isinstance(n, ast.Assign):
                for tgt in n.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == name:
                        out.append(n.value)
            elif (isinstance(n, ast.AnnAssign) and n.value is not None
                    and isinstance(n.target, ast.Name)
                    and n.target.id == name):
                out.append(n.value)
            stack.extend(ast.iter_child_nodes(n))
        return out

    def resolve_name(self, node: ast.AST, name: str):
        """Nearest lexical binding of ``name`` visible from ``node``: the
        expression assigned to it in the closest enclosing scope (None when
        unbound, rebound ambiguously, or bound by a non-Assign)."""
        for scope in self.enclosing_functions(node):
            vals = self._scope_assigns(scope, name)
            if len(vals) == 1:
                return vals[0]
            if vals:                 # rebound: ambiguous, refuse to guess
                return None
        return None

    def resolve_expr(self, node: ast.AST):
        """Chase a Name through single-assignment bindings to its value
        expression; other nodes pass through unchanged."""
        seen = 0
        while isinstance(node, ast.Name) and seen < 4:
            nxt = self.resolve_name(node, node.id)
            if nxt is None:
                return node
            node, seen = nxt, seen + 1
        return node


# ---------------------------------------------------------------- safe eval -
_ALLOWED_BINOPS = (ast.Add, ast.Sub, ast.Mult, ast.Mod, ast.FloorDiv,
                   ast.Pow)
_ALLOWED_CALLS = {"min": min, "max": max, "abs": abs}


def safe_eval_int(node: ast.AST, env: dict[str, int]):
    """Evaluate a small arithmetic expression over ints (the ppermute
    permutation grammar: +, -, *, %, //, min/max/abs, names bound in env).
    Returns None when the expression leaves that grammar."""
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) else None
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = safe_eval_int(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp) and isinstance(node.op, _ALLOWED_BINOPS):
        left = safe_eval_int(node.left, env)
        right = safe_eval_int(node.right, env)
        if left is None or right is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.Mod):
                return left % right
            if isinstance(node.op, ast.FloorDiv):
                return left // right
            if isinstance(node.op, ast.Pow):
                return left ** right
        except (ZeroDivisionError, ValueError):
            return None
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in _ALLOWED_CALLS and not node.keywords):
        args = [safe_eval_int(a, env) for a in node.args]
        if any(a is None for a in args):
            return None
        return _ALLOWED_CALLS[node.func.id](*args)
    return None


# ----------------------------------------------------------------- baseline -
class Baseline:
    """JSON allowlist: {"suppressions": {finding_key: reason}}."""

    def __init__(self, path: str):
        self.path = path
        self.suppressions: dict[str, str] = {}
        if path and os.path.exists(path):
            with open(path) as f:
                payload = json.load(f)
            self.suppressions = dict(payload.get("suppressions", {}))

    def split(self, findings: list[Finding]):
        """-> (unsuppressed, suppressed, stale_keys)."""
        live = {f.key for f in findings}
        unsup = [f for f in findings if f.key not in self.suppressions]
        sup = [f for f in findings if f.key in self.suppressions]
        stale = sorted(k for k in self.suppressions if k not in live)
        return unsup, sup, stale

    def update(self, findings: list[Finding]):
        """Adopt every current finding (keeping existing reasons) and prune
        entries whose finding no longer fires. Returns (added, pruned)."""
        live = {f.key for f in findings}
        added = sorted(k for k in live if k not in self.suppressions)
        pruned = sorted(k for k in self.suppressions if k not in live)
        self.suppressions = {
            k: self.suppressions.get(
                k, "adopted by --update — document why or fix the code")
            for k in sorted(live)}
        payload = {
            "_comment": ("chunklint suppressions (python -m repro.analysis). "
                         "Keys are check_id::path::detail — line-stable. "
                         "--update adopts current findings and prunes stale "
                         "entries; every entry should say WHY the finding is "
                         "a false positive or accepted debt."),
            "suppressions": self.suppressions,
        }
        with open(self.path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        return added, pruned


# ----------------------------------------------------------- axis registry --
def load_axis_registry(roots: list[str]) -> frozenset[str] | None:
    """Find the canonical MESH_AXES tuple by AST (never by import): prefer a
    ``launch/mesh.py``, else any ``mesh.py``, under the scanned roots."""
    candidates = []
    for root in roots:
        if os.path.isfile(root):
            root = os.path.dirname(root) or "."
        for dirpath, _, files in os.walk(root):
            for fn in files:
                if fn == "mesh.py":
                    p = os.path.join(dirpath, fn)
                    rank = 0 if dirpath.replace(os.sep, "/").endswith(
                        "launch") else 1
                    candidates.append((rank, p))
    for _, p in sorted(candidates):
        try:
            with open(p) as f:
                tree = ast.parse(f.read(), filename=p)
        except (OSError, SyntaxError):
            continue
        for node in tree.body:
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Name) and tgt.id == "MESH_AXES"
                            and isinstance(node.value, (ast.Tuple, ast.List))):
                        vals = [e.value for e in node.value.elts
                                if isinstance(e, ast.Constant)
                                and isinstance(e.value, str)]
                        if vals:
                            return frozenset(vals)
    return None


def iter_py_files(roots: list[str]):
    for root in roots:
        if os.path.isfile(root):
            yield root
            continue
        for dirpath, dirnames, files in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(files):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def run_analysis(roots: list[str], *, axes: frozenset[str] | None = None,
                 repo_root: str = ".") -> list[Finding]:
    """Parse every .py under ``roots`` and run all registered checks."""
    from repro.analysis.checks import ALL_CHECKS
    if axes is None:
        axes = load_axis_registry(roots)
    findings: list[Finding] = []
    for path in iter_py_files(roots):
        rel = os.path.relpath(path, repo_root)
        try:
            with open(path) as f:
                source = f.read()
            ctx = ModuleCtx(path, rel, source, axes)
        except SyntaxError as e:
            findings.append(Finding(
                "CF-PARSE", rel.replace(os.sep, "/"), e.lineno or 0, 0,
                f"file does not parse: {e.msg}", detail="syntax"))
            continue
        for check in ALL_CHECKS:
            findings.extend(check(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.check_id))
    return findings
