"""CF-VJP: jax.custom_vjp contract discipline.

The executors differentiate straight through ``custom_vjp`` attention
kernels, so a primal/fwd/bwd mismatch is a *silent* wrong-gradient bug (jax
only validates lazily, at trace time, on the code path that actually runs —
the analyzer checks every pair at rest).

  CF-VJP01  custom_vjp primal never wired up with f.defvjp(fwd, bwd)
  CF-VJP02  bwd arity mismatch: params != nondiff + (res, cotangent), or a
            literal return tuple != number of differentiable primal args
  CF-VJP03  residual mismatch: fwd packs N residuals, bwd unpacks M
  CF-VJP04  fwd signature does not match the primal's
  CF-VJP05  dead nondiff_argnums entry (index out of the primal's range)
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleCtx

CHECK_IDS = {
    "CF-VJP01": "custom_vjp primal has no defvjp(fwd, bwd) wiring",
    "CF-VJP02": "custom_vjp bwd arity / return-tuple length mismatch",
    "CF-VJP03": "custom_vjp residual pack/unpack length mismatch",
    "CF-VJP04": "custom_vjp fwd signature does not match the primal",
    "CF-VJP05": "dead nondiff_argnums index (out of the primal's arg range)",
}


def _arity(fn: ast.FunctionDef):
    """Positional arity, or None when *args makes it open-ended."""
    if fn.args.vararg is not None:
        return None
    return len(fn.args.posonlyargs) + len(fn.args.args)


def _custom_vjp_decoration(ctx: ModuleCtx, fn: ast.FunctionDef):
    """-> (is_custom_vjp, nondiff_argnums tuple or ()) for a FunctionDef."""
    for dec in fn.decorator_list:
        if ctx.qualname(dec).endswith("custom_vjp"):
            return True, ()
        if isinstance(dec, ast.Call):
            # @functools.partial(jax.custom_vjp, nondiff_argnums=(...)) or
            # @jax.custom_vjp(nondiff_argnums=...) style
            inner = [dec.func] + list(dec.args)
            if any(ctx.qualname(n).endswith("custom_vjp") for n in inner):
                nd = ()
                for kw in dec.keywords:
                    if kw.arg == "nondiff_argnums" and isinstance(
                            kw.value, (ast.Tuple, ast.List)):
                        nd = tuple(e.value for e in kw.value.elts
                                   if isinstance(e, ast.Constant)
                                   and isinstance(e.value, int))
                return True, nd
    return False, ()


def _find_def(ctx: ModuleCtx, name: str, near: ast.AST):
    """Resolve a function name lexically: prefer the def sharing ``near``'s
    innermost enclosing function (the nested fwd/bwd-per-closure idiom of
    kernels/chunked_attention.py, where two closures both define `fwd`)."""
    hits = [n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef) and n.name == name]
    if len(hits) == 1:
        return hits[0]
    scope = next(iter(ctx.enclosing_functions(near)), None)
    in_scope = [h for h in hits
                if next(iter(ctx.enclosing_functions(h)), None) is scope]
    return in_scope[0] if len(in_scope) == 1 else None


def _residual_pack_len(fwd: ast.FunctionDef):
    """fwd returns (out, res): length of res when it is a literal tuple."""
    for node in ast.walk(fwd):
        if (isinstance(node, ast.Return) and isinstance(node.value, ast.Tuple)
                and len(node.value.elts) == 2
                and isinstance(node.value.elts[1], (ast.Tuple, ast.List))):
            return len(node.value.elts[1].elts)
    return None


def _residual_unpack_len(bwd: ast.FunctionDef, res_name: str):
    """Length of the first ``a, b, ... = res`` unpacking in bwd."""
    for node in ast.walk(bwd):
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Name)
                and node.value.id == res_name
                and len(node.targets) == 1
                and isinstance(node.targets[0], (ast.Tuple, ast.List))):
            tgts = node.targets[0].elts
            if any(isinstance(t, ast.Starred) for t in tgts):
                return None
            return len(tgts)
    return None


def check(ctx: ModuleCtx) -> list[Finding]:
    out: list[Finding] = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        is_cvjp, nondiff = _custom_vjp_decoration(ctx, fn)
        if not is_cvjp:
            continue
        n_primal = _arity(fn)

        if n_primal is not None:
            dead = [i for i in nondiff if i >= n_primal]
            if dead:
                out.append(Finding(
                    "CF-VJP05", ctx.relpath, fn.lineno, fn.col_offset,
                    f"nondiff_argnums {dead} out of range for "
                    f"{fn.name}({n_primal} args)",
                    hint="drop the dead index — it silently shifts nothing "
                         "today and the wrong arg after a refactor",
                    detail=f"{fn.name}:nondiff"))

        # find <fn.name>.defvjp(fwd, bwd), preferring the primal's own scope
        # (nested per-closure custom_vjp pairs reuse names across closures)
        fn_scope = next(iter(ctx.enclosing_functions(fn)), None)
        wiring = None
        for call in ast.walk(ctx.tree):
            if (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and call.func.attr == "defvjp"
                    and isinstance(call.func.value, ast.Name)
                    and call.func.value.id == fn.name):
                same_scope = next(
                    iter(ctx.enclosing_functions(call)), None) is fn_scope
                if wiring is None or same_scope:
                    wiring = call
                if same_scope:
                    break
        if wiring is None or len(wiring.args) < 2:
            out.append(Finding(
                "CF-VJP01", ctx.relpath, fn.lineno, fn.col_offset,
                f"custom_vjp function {fn.name!r} is never wired: no "
                f"{fn.name}.defvjp(fwd, bwd) found",
                hint="call f.defvjp(fwd, bwd) right after defining the pair "
                     "— an unwired custom_vjp raises only when first "
                     "differentiated",
                detail=f"{fn.name}:defvjp"))
            continue

        fwd = (_find_def(ctx, wiring.args[0].id, wiring)
               if isinstance(wiring.args[0], ast.Name) else None)
        bwd = (_find_def(ctx, wiring.args[1].id, wiring)
               if isinstance(wiring.args[1], ast.Name) else None)

        if fwd is not None and n_primal is not None:
            n_fwd = _arity(fwd)
            if n_fwd is not None and n_fwd != n_primal:
                out.append(Finding(
                    "CF-VJP04", ctx.relpath, fwd.lineno, fwd.col_offset,
                    f"fwd {fwd.name!r} takes {n_fwd} args but primal "
                    f"{fn.name!r} takes {n_primal}",
                    hint="fwd receives exactly the primal's arguments "
                         "(nondiff included)",
                    detail=f"{fn.name}:fwd-arity"))

        n_expected_ct = (None if n_primal is None
                         else n_primal - len(nondiff))
        if bwd is not None:
            n_bwd = _arity(bwd)
            if n_bwd is not None and n_bwd != len(nondiff) + 2:
                out.append(Finding(
                    "CF-VJP02", ctx.relpath, bwd.lineno, bwd.col_offset,
                    f"bwd {bwd.name!r} takes {n_bwd} args, expected "
                    f"{len(nondiff) + 2} (nondiff args + residuals + "
                    "cotangent)",
                    hint="bwd signature is (*nondiff, res, ct)",
                    detail=f"{fn.name}:bwd-arity"))
            if n_expected_ct is not None:
                for ret in ast.walk(bwd):
                    if (isinstance(ret, ast.Return)
                            and isinstance(ret.value, (ast.Tuple, ast.List))
                            and not any(isinstance(e, ast.Starred)
                                        for e in ret.value.elts)
                            and len(ret.value.elts) != n_expected_ct):
                        out.append(Finding(
                            "CF-VJP02", ctx.relpath, ret.lineno,
                            ret.col_offset,
                            f"bwd {bwd.name!r} returns "
                            f"{len(ret.value.elts)} cotangents, expected "
                            f"{n_expected_ct} (one per differentiable "
                            "primal arg)",
                            hint="return None for non-differentiable array "
                                 "args; arity must still match",
                            detail=f"{fn.name}:bwd-return"))

        if fwd is not None and bwd is not None:
            n_res = _residual_pack_len(fwd)
            n_bwd_args = _arity(bwd)
            if n_res is not None and n_bwd_args is not None:
                res_param_idx = len(nondiff)
                params = bwd.args.posonlyargs + bwd.args.args
                if res_param_idx < len(params):
                    n_unpack = _residual_unpack_len(
                        bwd, params[res_param_idx].arg)
                    if n_unpack is not None and n_unpack != n_res:
                        out.append(Finding(
                            "CF-VJP03", ctx.relpath, bwd.lineno,
                            bwd.col_offset,
                            f"fwd {fwd.name!r} packs {n_res} residuals but "
                            f"bwd {bwd.name!r} unpacks {n_unpack}",
                            hint="keep the residual tuple and its unpacking "
                                 "in lockstep — a skew rotates every "
                                 "residual into the wrong slot",
                            detail=f"{fn.name}:residuals"))
    return out
