"""chunklint check registry — one module per check family.

Every module exposes ``check(ctx: ModuleCtx) -> list[Finding]`` and a
``CHECK_IDS`` dict mapping its IDs to one-line descriptions.
"""
from __future__ import annotations

from repro.analysis.checks import (
    custom_vjp,
    donation,
    mesh_axes,
    pallas_blockspec,
    ppermute_cycles,
    tracer_hygiene,
)

_MODULES = (mesh_axes, ppermute_cycles, custom_vjp, pallas_blockspec,
            tracer_hygiene, donation)

ALL_CHECKS = tuple(m.check for m in _MODULES)

ALL_CHECK_IDS: dict[str, str] = {}
for _m in _MODULES:
    ALL_CHECK_IDS.update(_m.CHECK_IDS)
