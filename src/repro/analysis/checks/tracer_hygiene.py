"""CF-TR: tracer hygiene inside jitted / shard_mapped / Pallas bodies.

Two hazards that parse fine and fail (or mislead) only at trace time:

* Python ``if``/``while`` branching on a *traced* expression — a jnp/lax
  call or ``pl.program_id`` — inside a traced context. These either raise a
  ConcretizationTypeError on the path that runs, or (with ``program_id``)
  should have been ``pl.when`` and never fire at all.
* a host-side ``jnp.*`` value computed in an enclosing function and closed
  over into a ``shard_map`` body: the constant is baked in replicated at
  trace time instead of arriving through ``in_specs``, bypassing the
  sharding contract the specs document.

  CF-TR01  Python if/while on a traced expression in a traced context
  CF-TR02  host-side jnp value closed over into a shard_map body
"""
from __future__ import annotations

import ast
import builtins

from repro.analysis.core import Finding, ModuleCtx

CHECK_IDS = {
    "CF-TR01": "Python if/while on a traced expression in a jit/shard_map/"
               "pallas body",
    "CF-TR02": "host-side jnp value closed over into a shard_map body",
}

# callees whose function-valued arguments become traced contexts
_TRACING_CALLEES = {"shard_map", "pallas_call", "scan", "cond", "while_loop",
                    "fori_loop", "vjp", "grad", "value_and_grad", "vmap",
                    "checkpoint", "remat", "jit", "eval_shape"}
_TRACED_PREFIXES = ("jax.numpy.", "jax.lax.")
_BUILTINS = frozenset(dir(builtins))


def _defs_by_name(ctx: ModuleCtx):
    table: dict[str, list[ast.FunctionDef]] = {}
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.FunctionDef):
            table.setdefault(n.name, []).append(n)
    return table


def _is_jit_decorated(ctx: ModuleCtx, fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        if ctx.qualname(dec).split(".")[-1] == "jit":
            return True
        if isinstance(dec, ast.Call):
            nodes = [dec.func] + list(dec.args)
            if any(ctx.qualname(n).split(".")[-1] in ("jit", "pallas_call")
                   for n in nodes):
                return True
    return False


def _traced_contexts(ctx: ModuleCtx):
    """-> (traced set of FunctionDef, {def: True} passed to shard_map)."""
    defs = _defs_by_name(ctx)
    traced: set[ast.FunctionDef] = set()
    via_shard_map: set[ast.FunctionDef] = set()

    def resolve_fn_arg(arg):
        if isinstance(arg, ast.Name) and len(defs.get(arg.id, [])) == 1:
            return defs[arg.id][0]
        # functools.partial(kernel, ...) wrapping a def
        if (isinstance(arg, ast.Call)
                and ctx.callee(arg).split(".")[-1] == "partial"
                and arg.args and isinstance(arg.args[0], ast.Name)
                and len(defs.get(arg.args[0].id, [])) == 1):
            return defs[arg.args[0].id][0]
        return None

    for fn in ast.walk(ctx.tree):
        if isinstance(fn, ast.FunctionDef) and _is_jit_decorated(ctx, fn):
            traced.add(fn)
    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call):
            continue
        name = ctx.callee(call)
        if name not in _TRACING_CALLEES:
            continue
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            fn = resolve_fn_arg(arg)
            if fn is not None:
                traced.add(fn)
                if name == "shard_map":
                    via_shard_map.add(fn)

    # nested defs inherit the traced context
    for fn in list(traced):
        for sub in ast.walk(fn):
            if isinstance(sub, ast.FunctionDef) and sub is not fn:
                traced.add(sub)
    return traced, via_shard_map


def _traced_test(ctx: ModuleCtx, test: ast.AST):
    """The jnp/lax/program_id call making a test traced, or None."""
    for node in ast.walk(test):
        if isinstance(node, ast.Call):
            qual = ctx.qualname(node.func)
            terminal = qual.split(".")[-1]
            if (qual.startswith(_TRACED_PREFIXES)
                    or terminal == "program_id"):
                return qual or terminal
    return None


def _module_globals(ctx: ModuleCtx) -> set[str]:
    names = set(ctx.imports)
    for node in ctx.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                for n in ast.walk(tgt):
                    if isinstance(n, ast.Name):
                        names.add(n.id)
    return names


def _bound_names(fn: ast.FunctionDef) -> set[str]:
    """Parameters + every name the body itself binds (incl. nested defs)."""
    a = fn.args
    bound = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    for va in (a.vararg, a.kwarg):
        if va is not None:
            bound.add(va.arg)
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)) and node is not fn:
            bound.add(node.name)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, ast.Lambda):
            bound.update(p.arg for p in node.args.args)
        elif isinstance(node, ast.comprehension):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    bound.add(n.id)
    return bound


def check(ctx: ModuleCtx) -> list[Finding]:
    out: list[Finding] = []
    traced, via_shard_map = _traced_contexts(ctx)

    for fn in traced:
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                qual = _traced_test(ctx, node.test)
                if qual:
                    kind = "while" if isinstance(node, ast.While) else "if"
                    out.append(Finding(
                        "CF-TR01", ctx.relpath, node.lineno, node.col_offset,
                        f"Python `{kind}` on traced expression "
                        f"({qual}(...)) inside traced context "
                        f"{fn.name!r}",
                        hint="use jnp.where / lax.cond / pl.when — Python "
                             "control flow needs a concrete bool and traced "
                             "values don't have one",
                        detail=f"{fn.name}:{kind}:{qual}"))

    globals_ = _module_globals(ctx)
    for fn in via_shard_map:
        bound = _bound_names(fn)
        reported = set()
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)):
                continue
            name = node.id
            if (name in bound or name in globals_ or name in _BUILTINS
                    or name in reported):
                continue
            binding = ctx.resolve_name(fn, name)
            if binding is None:
                continue
            if (isinstance(binding, ast.Call)
                    and ctx.qualname(binding.func).startswith("jax.numpy.")):
                reported.add(name)
                out.append(Finding(
                    "CF-TR02", ctx.relpath, node.lineno, node.col_offset,
                    f"shard_map body {fn.name!r} closes over host-side jnp "
                    f"value {name!r} (bound at line {binding.lineno})",
                    hint="pass it as an operand with an explicit in_spec — "
                         "closed-over arrays are baked in replicated and "
                         "bypass the sharding contract",
                    detail=f"{fn.name}:closure:{name}"))
    return out
