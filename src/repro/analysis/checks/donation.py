"""CF-DN: buffer-donation safety.

``donate_argnums`` hands the argument's device buffer to XLA; touching the
Python name afterwards dereferences a deleted array ("Array has been
deleted" — the exact crash PR 3's engine warmup hit on hardware, invisible
on CPU tests). The check finds call sites of jit-with-donation functions and
flags donated arguments that are read again afterwards without rebinding;
inside a loop, a donated name that the call statement does not rebind is
flagged too (the next iteration re-donates a dead buffer).

  CF-DN01  donated argument referenced after the donating call
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleCtx

CHECK_IDS = {
    "CF-DN01": "argument donated via donate_argnums is referenced after "
               "the call",
}


def _donated_positions(ctx: ModuleCtx, call_or_dec: ast.Call):
    """donate_argnums tuple from a jit(...) / partial(jax.jit, ...) call,
    chasing a Name through single assignment. None when absent/dynamic."""
    for kw in call_or_dec.keywords:
        if kw.arg != "donate_argnums":
            continue
        val = ctx.resolve_expr(kw.value)
        if isinstance(val, ast.Constant) and isinstance(val.value, int):
            return (val.value,)
        if isinstance(val, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in val.elts):
            return tuple(e.value for e in val.elts)
        return None
    return None


def _donating_functions(ctx: ModuleCtx):
    """-> {local name: donated positions} for jitted-with-donation defs:
    decorator form (@partial(jax.jit, donate_argnums=...)) and assignment
    form (step = jax.jit(f, donate_argnums=...))."""
    table: dict[str, tuple[int, ...]] = {}
    for fn in ast.walk(ctx.tree):
        if isinstance(fn, ast.FunctionDef):
            for dec in fn.decorator_list:
                if isinstance(dec, ast.Call):
                    nodes = [dec.func] + list(dec.args)
                    if any(ctx.qualname(n).split(".")[-1] == "jit"
                           for n in nodes):
                        pos = _donated_positions(ctx, dec)
                        if pos:
                            table[fn.name] = pos
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if ctx.qualname(call.func).split(".")[-1] == "jit":
                pos = _donated_positions(ctx, call)
                if pos:
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            table[tgt.id] = pos
    return table


def _stmt_of(ctx: ModuleCtx, node: ast.AST):
    """Nearest enclosing statement of an expression node."""
    cur = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = ctx.parents.get(cur)
    return cur


def _assigned_names(stmt: ast.stmt) -> set[str]:
    names: set[str] = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    for tgt in targets:
        for n in ast.walk(tgt):
            if isinstance(n, ast.Name):
                names.add(n.id)
    return names


def check(ctx: ModuleCtx) -> list[Finding]:
    out: list[Finding] = []
    donating = _donating_functions(ctx)
    if not donating:
        return out

    for call in ast.walk(ctx.tree):
        if not (isinstance(call, ast.Call) and isinstance(call.func, ast.Name)
                and call.func.id in donating):
            continue
        stmt = _stmt_of(ctx, call)
        if stmt is None:
            continue
        scope = next(iter(ctx.enclosing_functions(call)), ctx.tree)
        rebound = _assigned_names(stmt)
        cur, in_loop = ctx.parents.get(call), False
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
            if isinstance(cur, (ast.For, ast.While)):
                in_loop = True
            cur = ctx.parents.get(cur)

        for pos in donating[call.func.id]:
            if pos >= len(call.args):
                continue
            arg = call.args[pos]
            if not isinstance(arg, ast.Name):
                continue
            name = arg.id
            if name in rebound:
                continue        # params, opt = step(params, batch, opt)
            later_load = None
            for n in ast.walk(scope):
                if (isinstance(n, ast.Name) and n.id == name
                        and isinstance(n.ctx, ast.Load)
                        and n.lineno > stmt.lineno and n is not arg
                        and (later_load is None
                             or n.lineno < later_load.lineno)):
                    later_load = n
            if later_load is not None:
                out.append(Finding(
                    "CF-DN01", ctx.relpath, later_load.lineno,
                    later_load.col_offset,
                    f"{name!r} is donated to {call.func.id!r} (argnum {pos}, "
                    f"line {stmt.lineno}) but referenced again here — its "
                    "buffer is deleted after the call",
                    hint="rebind the result to the same name "
                         "(x, ... = f(x, ...)) or stop donating it",
                    detail=f"{call.func.id}:{pos}:{name}"))
            elif in_loop:
                out.append(Finding(
                    "CF-DN01", ctx.relpath, stmt.lineno, stmt.col_offset,
                    f"{name!r} is donated to {call.func.id!r} (argnum {pos}) "
                    "inside a loop without being rebound — the next "
                    "iteration re-donates a deleted buffer",
                    hint="rebind the result to the same name each iteration",
                    detail=f"{call.func.id}:{pos}:{name}:loop"))
    return out
