"""CF-RING: ppermute permutation soundness.

A ring collective's ``perm`` must be a *total bijection* over the axis: every
rank sends exactly once and receives exactly once. The motivating near-miss
is the dk/dv accumulator in ``kernels/chunked_attention.py`` — it rotates
WITH its kv shard and needs "one final hop home"; writing the shift as
``[(i, i + 1) for i in range(cp - 1)]`` (a non-cyclic shift) silently drops
rank cp-1's contribution and XLA will not complain.

Literal pair lists are checked directly; comprehensions over ``range(n)``
(``[(i, (i + 1) % cp) for i in range(cp)]``) are checked by sampling several
axis sizes and evaluating the index arithmetic with the core safe evaluator.
Permutations bound to a name (the ``perm = [...]`` closure idiom) are chased
through single-assignment bindings.

  CF-RING01  perm is not a bijection (duplicate source or destination)
  CF-RING02  perm is not total / not closed (sources != destinations set)
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleCtx, safe_eval_int

CHECK_IDS = {
    "CF-RING01": "ppermute perm has duplicate sources or destinations",
    "CF-RING02": "ppermute perm is not a total cycle over the axis "
                 "(source set != destination set)",
}

_SAMPLE_SIZES = (2, 3, 4, 5, 8)


def _pairs_from_literal(node: ast.AST):
    """[(src, dst), ...] from a literal list/tuple of int-pair literals, or
    None when any element leaves that grammar."""
    if not isinstance(node, (ast.List, ast.Tuple)):
        return None
    pairs = []
    for e in node.elts:
        if not (isinstance(e, (ast.Tuple, ast.List)) and len(e.elts) == 2):
            return None
        src = safe_eval_int(e.elts[0], {})
        dst = safe_eval_int(e.elts[1], {})
        if src is None or dst is None:
            return None
        pairs.append((src, dst))
    return pairs


def _pairs_from_comprehension(node: ast.AST, n: int):
    """Evaluate ``[(f(i), g(i)) for i in range(N)]`` at axis size ``n``.
    Returns the pair list, or None when the shape/grammar doesn't match."""
    if not isinstance(node, (ast.ListComp, ast.GeneratorExp)):
        return None
    if len(node.generators) != 1:
        return None
    gen = node.generators[0]
    if gen.ifs or not isinstance(gen.target, ast.Name):
        return None
    it = gen.iter
    if not (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
            and it.func.id == "range" and len(it.args) == 1):
        return None
    elt = node.elt
    if not (isinstance(elt, (ast.Tuple, ast.List)) and len(elt.elts) == 2):
        return None
    # free names in the range bound and the pair exprs all get the axis size:
    # the repo idiom is one size variable (cp) used for both.
    names = {nd.id for sub in (it.args[0], elt.elts[0], elt.elts[1])
             for nd in ast.walk(sub) if isinstance(nd, ast.Name)}
    names.discard(gen.target.id)
    env = {name: n for name in names}
    count = safe_eval_int(it.args[0], env)
    if count is None or count < 0 or count > 64:
        return None
    pairs = []
    for i in range(count):
        env_i = dict(env, **{gen.target.id: i})
        src = safe_eval_int(elt.elts[0], env_i)
        dst = safe_eval_int(elt.elts[1], env_i)
        if src is None or dst is None:
            return None
        pairs.append((src, dst))
    return pairs


def _verdict(pairs):
    """-> (check_id, problem) or None for a sound permutation."""
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    if len(set(srcs)) != len(srcs):
        return "CF-RING01", "duplicate source ranks"
    if len(set(dsts)) != len(dsts):
        return "CF-RING01", "duplicate destination ranks (two senders " \
                            "target one rank)"
    if set(srcs) != set(dsts):
        return "CF-RING02", (
            f"source set {sorted(set(srcs))} != destination set "
            f"{sorted(set(dsts))} — some rank never receives its buffer back")
    return None


def check(ctx: ModuleCtx) -> list[Finding]:
    out: list[Finding] = []
    for call in ctx.calls("ppermute"):
        perm = None
        if len(call.args) >= 3:
            perm = call.args[2]
        for kw in call.keywords:
            if kw.arg == "perm":
                perm = kw.value
        if perm is None:
            continue
        perm = ctx.resolve_expr(perm)

        lit = _pairs_from_literal(perm)
        if lit is not None:
            v = _verdict(lit)
            if v:
                cid, problem = v
                out.append(Finding(
                    cid, ctx.relpath, call.lineno, call.col_offset,
                    f"ppermute perm {lit} is unsound: {problem}",
                    hint="a ring rotation must be a full cycle, e.g. "
                         "[(i, (i + 1) % n) for i in range(n)]",
                    detail=f"literal:{lit}"))
            continue

        for n in _SAMPLE_SIZES:
            pairs = _pairs_from_comprehension(perm, n)
            if pairs is None:
                break                       # grammar mismatch: skip silently
            v = _verdict(pairs)
            if v:
                cid, problem = v
                out.append(Finding(
                    cid, ctx.relpath, call.lineno, call.col_offset,
                    f"ppermute perm is unsound at axis size {n}: {problem} "
                    f"(evaluated {pairs})",
                    hint="a ring rotation must be a full cycle, e.g. "
                         "[(i, (i + 1) % n) for i in range(n)]; shifts that "
                         "skip ranks or stop at n-1 drop contributions",
                    detail=f"comprehension@n={n}"))
                break
    return out
