"""CF-PL: Pallas BlockSpec / grid discipline.

Pallas index-map arity errors surface as opaque trace-time explosions (or,
worse, silently index the wrong block when a lambda swallows an extra grid
axis through defaults). The contract being checked:

* a BlockSpec index map takes exactly ``grid rank + num_scalar_prefetch``
  parameters (scalar-prefetch refs are appended to the grid indices);
* an out_specs block shape has the same rank as the paired ``out_shape``
  ShapeDtypeStruct;
* the number of operands passed to the compiled ``pallas_call(...)``
  matches ``num_scalar_prefetch + len(in_specs)``.

  CF-PL01  index-map lambda arity != grid rank (+ scalar-prefetch count)
  CF-PL02  out_specs block-shape rank != out_shape rank
  CF-PL03  operand count != num_scalar_prefetch + len(in_specs)
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleCtx

CHECK_IDS = {
    "CF-PL01": "BlockSpec index-map arity != grid rank + scalar prefetch",
    "CF-PL02": "out_specs block-shape rank != out_shape rank",
    "CF-PL03": "pallas_call operand count != prefetch + len(in_specs)",
}


def _tuple_len(node: ast.AST):
    return len(node.elts) if isinstance(node, (ast.Tuple, ast.List)) else None


def _kwarg(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _block_specs(node: ast.AST, ctx: ModuleCtx):
    """Direct BlockSpec(...) calls lexically under a specs expression (walks
    through list/tuple/concat structure; helper-built specs are opaque)."""
    if node is None:
        return []
    return [n for n in ast.walk(node)
            if isinstance(n, ast.Call) and ctx.callee(n) == "BlockSpec"]


def _index_map(spec: ast.Call):
    im = _kwarg(spec, "index_map")
    if im is None and len(spec.args) >= 2:
        im = spec.args[1]
    return im if isinstance(im, ast.Lambda) else None


def _block_shape(spec: ast.Call):
    bs = _kwarg(spec, "block_shape")
    if bs is None and spec.args:
        bs = spec.args[0]
    return bs


def _sds_rank(node: ast.AST, ctx: ModuleCtx):
    """Rank of a literal-shaped jax.ShapeDtypeStruct(...) call, else None."""
    node = ctx.resolve_expr(node)
    if isinstance(node, ast.Call) and ctx.callee(node) == "ShapeDtypeStruct":
        shape = _kwarg(node, "shape")
        if shape is None and node.args:
            shape = node.args[0]
        return _tuple_len(shape)
    return None


def check(ctx: ModuleCtx) -> list[Finding]:
    out: list[Finding] = []
    for call in ctx.calls("pallas_call"):
        grid = ctx.resolve_expr(_kwarg(call, "grid")) \
            if _kwarg(call, "grid") is not None else None
        in_specs = _kwarg(call, "in_specs")
        out_specs = _kwarg(call, "out_specs")
        n_prefetch = 0

        gs = _kwarg(call, "grid_spec")
        if gs is not None:
            gs = ctx.resolve_expr(gs)
            if isinstance(gs, ast.Call) and ctx.callee(gs) in (
                    "PrefetchScalarGridSpec", "GridSpec"):
                pf = _kwarg(gs, "num_scalar_prefetch")
                if isinstance(pf, ast.Constant) and isinstance(pf.value, int):
                    n_prefetch = pf.value
                if _kwarg(gs, "grid") is not None:
                    grid = ctx.resolve_expr(_kwarg(gs, "grid"))
                in_specs = in_specs or _kwarg(gs, "in_specs")
                out_specs = out_specs or _kwarg(gs, "out_specs")

        grid_rank = _tuple_len(grid)
        want_arity = None if grid_rank is None else grid_rank + n_prefetch

        # --- CF-PL01: index-map arity -----------------------------------
        if want_arity is not None:
            for spec in (_block_specs(in_specs, ctx)
                         + _block_specs(out_specs, ctx)):
                lam = _index_map(spec)
                if lam is None or lam.args.vararg is not None:
                    continue
                n_lam = len(lam.args.posonlyargs) + len(lam.args.args)
                if n_lam != want_arity:
                    out.append(Finding(
                        "CF-PL01", ctx.relpath, lam.lineno, lam.col_offset,
                        f"BlockSpec index map takes {n_lam} args but the "
                        f"grid has rank {grid_rank}"
                        + (f" + {n_prefetch} scalar-prefetch ref(s)"
                           if n_prefetch else ""),
                        hint="index maps receive one arg per grid axis, "
                             "then one per scalar-prefetch operand",
                        detail=f"index-map-arity:{n_lam}vs{want_arity}"))

        # --- CF-PL02: out block rank vs out_shape rank -------------------
        out_shape = _kwarg(call, "out_shape")
        if out_specs is not None and out_shape is not None:
            specs_t = (out_specs.elts
                       if isinstance(out_specs, (ast.Tuple, ast.List))
                       else [out_specs])
            shapes_t = (out_shape.elts
                        if isinstance(out_shape, (ast.Tuple, ast.List))
                        else [out_shape])
            if len(specs_t) == len(shapes_t):
                for spec, sds in zip(specs_t, shapes_t):
                    if not (isinstance(spec, ast.Call)
                            and ctx.callee(spec) == "BlockSpec"):
                        continue
                    br = _tuple_len(_block_shape(spec))
                    sr = _sds_rank(sds, ctx)
                    if br is not None and sr is not None and br != sr:
                        out.append(Finding(
                            "CF-PL02", ctx.relpath, spec.lineno,
                            spec.col_offset,
                            f"out_specs block shape has rank {br} but the "
                            f"paired out_shape has rank {sr}",
                            hint="block shapes index into the full output "
                                 "shape — the ranks must agree",
                            detail=f"out-rank:{br}vs{sr}"))

        # --- CF-PL03: operand count -------------------------------------
        parent = ctx.parents.get(call)
        if (isinstance(parent, ast.Call) and parent.func is call
                and not any(isinstance(a, ast.Starred) for a in parent.args)
                and not parent.keywords):
            n_in = _tuple_len(in_specs) if isinstance(
                in_specs, (ast.Tuple, ast.List)) else None
            if n_in is not None:
                want = n_prefetch + n_in
                got = len(parent.args)
                if got != want:
                    out.append(Finding(
                        "CF-PL03", ctx.relpath, parent.lineno,
                        parent.col_offset,
                        f"pallas_call invoked with {got} operands but "
                        f"num_scalar_prefetch({n_prefetch}) + "
                        f"len(in_specs)({n_in}) = {want}",
                        hint="scalar-prefetch operands come first, then one "
                             "array per in_spec",
                        detail=f"operands:{got}vs{want}"))
    return out
