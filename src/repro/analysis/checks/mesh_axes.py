"""CF-AX: mesh-axis registry discipline.

Every axis *string literal* in a collective / sharding call site must come
from the canonical ``MESH_AXES`` registry in ``launch/mesh.py``. A typo'd
axis name in a ``PartitionSpec`` is the nastiest failure in the repo: GSPMD
treats an unknown axis spec as unconstrained/replicated, the program still
runs, and the loss is wrong-but-plausible.

  CF-AX01  axis literal not in the canonical registry
  CF-AX02  no MESH_AXES registry found anywhere under the scanned roots
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleCtx

CHECK_IDS = {
    "CF-AX01": "axis string not in the canonical MESH_AXES registry",
    "CF-AX02": "no MESH_AXES registry found under the scanned roots",
}

# callee terminal names whose axis argument(s) we inspect. For each: the
# positional index of the axis arg (None = kwargs only) and accepted kwargs.
_COLLECTIVES = {
    "ppermute": (1, ("axis_name",)),
    "psum": (1, ("axis_name",)),
    "pmean": (1, ("axis_name",)),
    "pmax": (1, ("axis_name",)),
    "pmin": (1, ("axis_name",)),
    "all_gather": (1, ("axis_name",)),
    "all_to_all": (1, ("axis_name",)),
    "axis_index": (0, ("axis_name",)),
    "pcast": (1, ("axes",)),
    "pcast_varying": (1, ("axes",)),
    "psum_scatter": (1, ("axis_name",)),
}

# mesh constructors: (positional index of the axis-names arg, kwarg names)
_MESH_CTORS = {
    "make_mesh": (1, ("axis_names",)),
    "Mesh": (1, ("axis_names",)),
}


def _axis_literals(node: ast.AST):
    """Yield (str, node) for every string literal in an axis-arg expression
    (plain literal, or nested in tuples/lists for multi-axis collectives)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value, node
    elif isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            yield from _axis_literals(e)


def _is_partition_spec(ctx: ModuleCtx, call: ast.Call) -> bool:
    name = ctx.callee(call)
    if name == "PartitionSpec":
        return True
    if name == "P":
        # only when this module aliases PartitionSpec to P (the repo idiom:
        # ``from jax.sharding import PartitionSpec as P``)
        return ctx.imports.get("P", "").endswith("PartitionSpec")
    return False


def check(ctx: ModuleCtx) -> list[Finding]:
    out: list[Finding] = []
    if ctx.axes is None:
        # Report once per module that has axis-bearing call sites, so the
        # failure mode is loud instead of silently skipping the family.
        for call in ast.walk(ctx.tree):
            if not isinstance(call, ast.Call):
                continue
            if not (_is_partition_spec(ctx, call)
                    or ctx.callee(call) in _COLLECTIVES
                    or ctx.callee(call) in _MESH_CTORS):
                continue
            out.append(Finding(
                "CF-AX02", ctx.relpath, call.lineno, call.col_offset,
                "cannot validate axis names: no MESH_AXES registry found "
                "under the scanned roots",
                hint="declare MESH_AXES = (...) in launch/mesh.py or pass "
                     "--axes",
                detail="missing-registry"))
            return out
        return out

    def flag(lit: str, node: ast.AST, where: str):
        out.append(Finding(
            "CF-AX01", ctx.relpath, node.lineno, node.col_offset,
            f'axis "{lit}" in {where} is not in the canonical mesh-axis '
            f"registry {sorted(ctx.axes)}",
            hint="fix the typo or register the axis in "
                 "launch/mesh.py MESH_AXES first",
            detail=f"{where}:{lit}"))

    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call):
            continue
        name = ctx.callee(call)
        if _is_partition_spec(ctx, call):
            for arg in call.args:
                for lit, node in _axis_literals(arg):
                    if lit not in ctx.axes:
                        flag(lit, node, "PartitionSpec")
        elif name in _COLLECTIVES:
            pos, kws = _COLLECTIVES[name]
            exprs = []
            if pos is not None and len(call.args) > pos:
                exprs.append(call.args[pos])
            exprs += [kw.value for kw in call.keywords if kw.arg in kws]
            for e in exprs:
                for lit, node in _axis_literals(e):
                    if lit not in ctx.axes:
                        flag(lit, node, name)
        elif name in _MESH_CTORS:
            pos, kws = _MESH_CTORS[name]
            exprs = []
            if len(call.args) > pos:
                exprs.append(call.args[pos])
            exprs += [kw.value for kw in call.keywords if kw.arg in kws]
            for e in exprs:
                for lit, node in _axis_literals(e):
                    if lit not in ctx.axes:
                        flag(lit, node, name)
    return out
