"""chunklint CLI.

    PYTHONPATH=src python -m repro.analysis [paths ...]
        [--baseline src/repro/analysis/baseline.json] [--update]
        [--axes data,pipe,seq] [--json FILE] [--list-checks]

Exit status: 0 when every finding is suppressed by the baseline (or there
are none), 1 otherwise. ``--update`` rewrites the baseline from the current
findings — adopting new ones and pruning stale entries — the same idiom as
``benchmarks/check_regression.py --update``: run it locally when a finding
is a documented false positive, then edit the adopted entry's reason and
commit the diff.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.checks import ALL_CHECK_IDS
from repro.analysis.core import Baseline, run_analysis

DEFAULT_BASELINE = "src/repro/analysis/baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="chunklint: mesh/kernel contract static analysis")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="suppression allowlist JSON (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report every finding")
    ap.add_argument("--update", action="store_true",
                    help="adopt current findings into the baseline and "
                         "prune stale entries")
    ap.add_argument("--axes", default=None,
                    help="comma-separated canonical axis names (default: "
                         "parsed from MESH_AXES in launch/mesh.py)")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="also write the findings report as JSON")
    ap.add_argument("--list-checks", action="store_true",
                    help="print every check ID and exit")
    args = ap.parse_args(argv)

    if args.list_checks:
        for cid in sorted(ALL_CHECK_IDS):
            print(f"{cid}  {ALL_CHECK_IDS[cid]}")
        return 0

    roots = args.paths or ["src"]
    axes = (frozenset(a.strip() for a in args.axes.split(",") if a.strip())
            if args.axes else None)
    findings = run_analysis(roots, axes=axes)

    baseline = Baseline("" if args.no_baseline else args.baseline)
    if args.update:
        added, pruned = baseline.update(findings)
        print(f"chunklint --update: {args.baseline}: "
              f"{len(added)} adopted, {len(pruned)} pruned, "
              f"{len(baseline.suppressions)} total suppressions")
        for k in added:
            print(f"  + {k}")
        for k in pruned:
            print(f"  - {k}")
        return 0

    unsup, sup, stale = baseline.split(findings)
    for f in unsup:
        print(f.render())

    if args.json:
        payload = {
            "checks": ALL_CHECK_IDS,
            "unsuppressed": [vars(f) | {"key": f.key} for f in unsup],
            "suppressed": [vars(f) | {"key": f.key} for f in sup],
            "stale_baseline_keys": stale,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")

    if stale:
        # stale suppressions rot into blanket permission for future bugs at
        # the same site — fail closed, same as check_regression's orphan gate
        print(f"chunklint: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (finding no longer "
              "fires) — run --update to prune:")
        for k in stale:
            print(f"  - {k}")
    summary = (f"chunklint: {len(unsup)} unsuppressed finding(s), "
               f"{len(sup)} suppressed, {len(stale)} stale baseline entries")
    print(summary)
    return 1 if (unsup or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
