"""Continuous-batching serving engine (ChunkFlow chunks meet an online
workload).

    frontend   — Request/RequestResult dataclasses, Poisson/trace arrival
                 simulation, streaming token callbacks
    kv_pages   — paged KV pool free-list allocator (StateStore page layout)
    scheduler  — FCFS admission + token-work prefill packer + preemption
    engine     — the single-jit static-shape engine step + host tick loop
"""
from repro.serving.engine import Engine, TRACE_EVENTS, reset_trace_log  # noqa: F401
from repro.serving.frontend import (Request, RequestResult,  # noqa: F401
                                    poisson_requests, trace_requests)
from repro.serving.kv_pages import NULL_PAGE, PagePool  # noqa: F401
from repro.serving.scheduler import EngineConfig, Scheduler  # noqa: F401
