"""Continuous-batching engine: ONE jitted, static-shape step per tick.

Every tick spends a fixed token budget on the same-shaped work regardless of
what requests are in flight:

  * ``max_running`` decode rows — one token per running request through
    `models.decode.decode_step_paged` (per-request cache lengths + page
    tables; idle rows point at the null page and are masked);
  * ``prefill_slots`` chunk rows of ``prefill_chunk`` tokens — ChunkFlow
    chunks of admitted prompts run through `models.api.forward` against a
    capacity-padded prefix *gathered through the page table*, and their new
    K/V is scattered back into whole pages (chunk size is a multiple of the
    page size, so chunks and pages tile each other exactly).

Because admission, packing and preemption all happen host-side in the
scheduler, the device function's shapes depend only on EngineConfig — the
step compiles exactly once (see TRACE_EVENTS) and peak KV memory is the pool
allocation ``pages_total * page_size`` slots, independent of the longest
prompt in the trace.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, decode
from repro.serving.kv_pages import PagePool
from repro.serving.scheduler import EngineConfig, Scheduler

TRACE_EVENTS = []       # one entry per Python trace of the engine step


def reset_trace_log():
    TRACE_EVENTS.clear()


class Engine:
    def __init__(self, cfg, params, ecfg: EngineConfig = None, dtype=None):
        ecfg = ecfg or EngineConfig()
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                f"serving engine supports attention families (dense/moe/vlm);"
                f" got {cfg.family!r}")
        ecfg.validate()
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self.cache = decode.init_paged_cache(cfg, ecfg.pages_total,
                                             ecfg.page_size, dtype)
        self.pool = PagePool(ecfg.pages_total)
        self.sched = Scheduler(ecfg, self.pool)
        self.now = 0.0
        self.ticks = 0
        self.stats = {"decode_tokens": 0, "prefill_tokens": 0,
                      "prefill_pad_tokens": 0, "empty_ticks": 0}
        self._step = self._build_step()

    # ------------------------------------------------------------ device ----
    @property
    def kv_pool_bytes(self) -> int:
        """Peak KV memory — fixed at construction, never grows."""
        return self.cache["k"].nbytes + self.cache["v"].nbytes

    def _build_step(self):
        cfg, ecfg = self.cfg, self.ecfg
        R, C, S = ecfg.max_running, ecfg.prefill_chunk, ecfg.prefill_slots
        maxp, ps = ecfg.max_pages_per_req, ecfg.page_size
        Kpre = maxp * ps                  # static prefix capacity (gathered)
        npg = C // ps                     # whole pages per prefill chunk

        def prefill_one(params, kp, vp, tok, pos, seg, table, prefix_len,
                        last_idx):
            """One (1, C) ChunkFlow chunk against a page-gathered prefix.
            Inactive slots (all-zero table, seg=0) compute garbage that only
            ever lands on the null page."""
            Lk, H, hd = kp.shape[0], kp.shape[3], kp.shape[4]
            pk = kp[:, table].reshape(Lk, 1, Kpre, H, hd)
            pv = vp[:, table].reshape(Lk, 1, Kpre, H, hd)
            slots_abs = jnp.arange(Kpre, dtype=jnp.int32)
            st = {"k": pk, "v": pv, "pos": slots_abs[None],
                  "seg": (slots_abs < prefix_len).astype(jnp.int32)[None]}
            positions = pos[None]
            if cfg.mrope:
                positions = jnp.stack([positions] * 3, -1)
            batch = {"tokens": tok[None], "segment_ids": seg[None],
                     "positions": positions}
            logits, new_state, _ = api.forward(cfg, params, batch, st)
            own_k = new_state["k"][:, 0, Kpre:]          # (L, C, H, hd)
            own_v = new_state["v"][:, 0, Kpre:]
            pages = jax.lax.dynamic_slice(table, (prefix_len // ps,), (npg,))
            kp = kp.at[:, pages].set(
                own_k.reshape(Lk, npg, ps, H, hd).astype(kp.dtype))
            vp = vp.at[:, pages].set(
                own_v.reshape(Lk, npg, ps, H, hd).astype(vp.dtype))
            nxt = jnp.argmax(logits[0, last_idx]).astype(jnp.int32)
            return kp, vp, nxt

        def step(params, kp, vp, dec_tok, dec_lens, dec_tables,
                 pre_tok, pre_pos, pre_seg, pre_tables, pre_prefix,
                 pre_last):
            TRACE_EVENTS.append(("engine_step", R, C, S))
            nxts = []
            for s in range(S):            # static unroll over chunk slots
                kp, vp, nxt = prefill_one(params, kp, vp, pre_tok[s],
                                          pre_pos[s], pre_seg[s],
                                          pre_tables[s], pre_prefix[s],
                                          pre_last[s])
                nxts.append(nxt)
            pre_next = (jnp.stack(nxts) if nxts
                        else jnp.zeros((0,), jnp.int32))
            logits, cache = decode.decode_step_paged(
                cfg, params, {"k": kp, "v": vp}, dec_tok, dec_lens,
                dec_tables)
            dec_next = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            return cache["k"], cache["v"], dec_next, pre_next

        # pool buffers are donated where the backend supports it (CPU doesn't
        # implement donation and would warn on every dispatch)
        donate = (1, 2) if jax.default_backend() != "cpu" else ()
        return jax.jit(step, donate_argnums=donate)

    # -------------------------------------------------------------- host ----
    def submit(self, req):
        return self.sched.submit(req, self.now)

    def tick(self, now: float = None) -> bool:
        """One engine tick. Returns True if any work was scheduled."""
        self.now = float(now) if now is not None else self.now + 1.0
        self.sched.admit(self.now)
        plan = self.sched.plan_tick(self.now)
        if not plan.decode and not plan.prefill:
            # idle (e.g. waiting on arrivals): don't burn a full device step
            self.ticks += 1
            self.stats["empty_ticks"] += 1
            return False
        e = self.ecfg
        R, C, S, maxp = (e.max_running, e.prefill_chunk, e.prefill_slots,
                         e.max_pages_per_req)

        dec_tok = np.zeros((R, 1), np.int32)
        dec_lens = np.zeros((R,), np.int32)
        dec_tables = np.zeros((R, maxp), np.int32)
        for s in plan.decode:
            dec_tok[s.slot, 0] = s.generated[-1]
            dec_lens[s.slot] = s.cache_len
            dec_tables[s.slot, :len(s.pages)] = s.pages

        pre_tok = np.zeros((S, C), np.int32)
        pre_pos = np.zeros((S, C), np.int32)
        pre_seg = np.zeros((S, C), np.int32)
        pre_tables = np.zeros((S, maxp), np.int32)
        pre_prefix = np.zeros((S,), np.int32)
        pre_last = np.zeros((S,), np.int32)
        for i, (s, start, n_real) in enumerate(plan.prefill):
            ext = s.ext_prompt
            pre_tok[i, :n_real] = ext[start:start + n_real]
            pre_pos[i] = start + np.arange(C)
            pre_seg[i, :n_real] = 1
            pre_tables[i, :len(s.pages)] = s.pages
            pre_prefix[i] = start
            pre_last[i] = n_real - 1

        k, v, dec_next, pre_next = self._step(
            self.params, self.cache["k"], self.cache["v"],
            jnp.asarray(dec_tok), jnp.asarray(dec_lens),
            jnp.asarray(dec_tables), jnp.asarray(pre_tok),
            jnp.asarray(pre_pos), jnp.asarray(pre_seg),
            jnp.asarray(pre_tables), jnp.asarray(pre_prefix),
            jnp.asarray(pre_last))
        self.cache = {"k": k, "v": v}

        dec_next = np.asarray(dec_next)
        pre_next = np.asarray(pre_next)
        for s in plan.decode:
            self.sched.commit_decode(s, int(dec_next[s.slot]), self.now)
        for i, (s, start, n_real) in enumerate(plan.prefill):
            self.sched.commit_prefill(s, start, n_real, int(pre_next[i]),
                                      self.now)

        self.ticks += 1
        self.stats["decode_tokens"] += len(plan.decode)
        self.stats["prefill_tokens"] += sum(n for _, _, n in plan.prefill)
        self.stats["prefill_pad_tokens"] += sum(C - n
                                                for _, _, n in plan.prefill)
        return True

    def warmup(self) -> None:
        """Compile the engine step off the measured path (a null dispatch —
        all rows idle, writes land on the null page). The pool buffers are
        donated to the step on accelerator backends, so the returned K/V must
        be reinstalled as the live cache."""
        e = self.ecfg
        z = np.zeros
        k, v, _, _ = self._step(
            self.params, self.cache["k"], self.cache["v"],
            jnp.asarray(z((e.max_running, 1), np.int32)),
            jnp.asarray(z((e.max_running,), np.int32)),
            jnp.asarray(z((e.max_running, e.max_pages_per_req), np.int32)),
            jnp.asarray(z((e.prefill_slots, e.prefill_chunk), np.int32)),
            jnp.asarray(z((e.prefill_slots, e.prefill_chunk), np.int32)),
            jnp.asarray(z((e.prefill_slots, e.prefill_chunk), np.int32)),
            jnp.asarray(z((e.prefill_slots, e.max_pages_per_req), np.int32)),
            jnp.asarray(z((e.prefill_slots,), np.int32)),
            jnp.asarray(z((e.prefill_slots,), np.int32)))
        self.cache = {"k": k, "v": v}

    def run(self, requests, *, clock: str = "ticks",
            max_ticks: int = 1_000_000) -> list:
        """Feed ``requests`` by arrival time and tick until all complete.

        clock="ticks": simulated time, 1.0 per tick (arrival_time in ticks —
        deterministic, what the tests use). clock="wall": wall seconds
        (arrival_time in seconds — what the latency benchmark uses).
        """
        assert clock in ("ticks", "wall")
        arr = lambda r: r.arrival_time if r.arrival_time is not None else 0.0
        pending = sorted(requests, key=lambda r: (arr(r), r.req_id))
        results, i = [], 0
        t0 = time.perf_counter()
        while i < len(pending) or not self.sched.idle:
            if self.ticks >= max_ticks:
                raise RuntimeError(f"engine exceeded max_ticks={max_ticks}")
            now = (self.ticks + 1.0 if clock == "ticks"
                   else time.perf_counter() - t0)
            while i < len(pending) and arr(pending[i]) <= now:
                results.append(self.submit(pending[i]))
                i += 1
            if not self.tick(now) and clock == "wall":
                time.sleep(1e-3)             # idle: wait for arrivals
        return results

    def summary(self) -> dict:
        return {
            "ticks": self.ticks,
            "token_budget_per_tick": self.ecfg.token_budget,
            "kv_pool_bytes": self.kv_pool_bytes,
            "kv_pages_peak_in_use": self.pool.peak_in_use,
            "n_preemptions": self.sched.n_preemptions,
            **self.stats,
        }
