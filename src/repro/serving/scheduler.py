"""Chunk-centric admission scheduler for the continuous-batching engine.

Responsibilities (all host-side, pure Python — the device step stays
static-shape and compiled once):

  * FCFS admission: a waiting request is admitted when a batch slot is free
    AND the page pool can hold its chunk-padded prompt. Strict FCFS — the
    head of the queue blocks later arrivals (no head-of-line bypass), which
    keeps admission order deterministic for the equivalence tests.
  * Prefill packing: each tick has ``prefill_slots`` chunk slots of
    ``prefill_chunk`` tokens and a token-work budget; the packer charges
    decode first (one token per running request, quadratic in context via
    `core.dp_balance.chunk_token_work`) and rides prefill chunks along FCFS
    until the budget is spent. ChunkFlow's Algorithm-2 phase 1 *is* the
    prefill: chunk ``i`` of a prompt attends to the ``i*C`` prefix already
    scattered into its pages.
  * Decode growth + preemption: before a request decodes into a fresh page,
    one page is allocated; if the pool is exhausted the *youngest* admitted
    request is preempted — its pages are released and it re-queues at the
    front (resume-by-recompute: prompt + generated tokens re-prefill, greedy
    decode regenerates identically). KV pages are therefore never
    oversubscribed, by construction.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.core.dp_balance import chunk_token_work
from repro.core.statestore import pages_needed, round_up
from repro.serving.frontend import Request, RequestResult
from repro.serving.kv_pages import PagePool


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine geometry — everything the jitted step's shapes depend
    on. One EngineConfig == one compile."""
    page_size: int = 16            # KV slots per page
    pages_total: int = 128         # pool pages incl. the reserved null page 0
    max_running: int = 4           # decode batch slots (R)
    prefill_chunk: int = 32        # tokens per prefill chunk slot (C)
    prefill_slots: int = 1         # prefill chunks that can ride along a tick
    max_pages_per_req: int = 32    # page-table width (max_model_len / page)
    mixed: bool = True             # False = prefill stalls decode (baseline)
    tick_work_budget: Optional[float] = None   # token-work cap per tick

    @property
    def max_model_len(self) -> int:
        return self.max_pages_per_req * self.page_size

    @property
    def token_budget(self) -> int:
        """Upper bound on tokens processed per tick (decode + prefill)."""
        return self.max_running + self.prefill_slots * self.prefill_chunk

    def validate(self):
        assert self.page_size >= 1 and self.pages_total >= 2
        assert self.max_running >= 1
        assert self.prefill_slots >= 1, \
            "prefill is the only path to decode phase; prefill_slots=0 can " \
            "never make progress"
        assert self.prefill_chunk >= 1
        assert self.prefill_chunk % self.page_size == 0, \
            "prefill_chunk must be a whole number of pages (chunk scatter " \
            "writes full pages)"


@dataclasses.dataclass
class _Pending:
    """Waiting-queue entry. ``generated`` is non-empty for preempted
    requests being resumed: their effective prompt is prompt + generated."""
    req: Request
    result: RequestResult
    generated: list

    @property
    def ext_len(self) -> int:
        return self.req.prompt_len + len(self.generated)


@dataclasses.dataclass
class SlotState:
    slot: int
    req: Request
    result: RequestResult
    generated: list                # tokens emitted so far (survives preempt)
    pages: list                    # owned pool pages, table order
    admit_seq: int                 # admission order (preemption priority)
    prefill_target: int            # tokens to prefill = prompt+generated at
                                   # admission (frozen: `generated` grows)
    phase: str = "prefill"         # "prefill" | "decode"
    prefill_done: int = 0          # tokens of ext prompt already prefilled
    _decoded: int = 0              # KV slots written by decode since admission

    @property
    def ext_prompt(self):
        import numpy as np
        gen = self.generated[:self.prefill_target - self.req.prompt_len]
        if not gen:
            return self.req.prompt
        return np.concatenate([self.req.prompt,
                               np.asarray(gen, self.req.prompt.dtype)])

    @property
    def cache_len(self) -> int:
        """Decode write slot: prefilled extent + decode tokens written."""
        return self.prefill_target + self._decoded


@dataclasses.dataclass
class TickPlan:
    decode: list                   # [SlotState] decoding this tick
    prefill: list                  # [(SlotState, start, n_real)] chunks


class Scheduler:
    def __init__(self, ecfg: EngineConfig, pool: PagePool):
        ecfg.validate()
        self.ecfg = ecfg
        self.pool = pool
        self.waiting = deque()
        self.slots = [None] * ecfg.max_running
        self.finished = []
        self._admit_seq = 0
        self.n_preemptions = 0

    # ------------------------------------------------------------ intake ----
    def _required_pages(self, pending: _Pending) -> int:
        """Worst-case pages the request can ever hold: its chunk-padded
        extended prompt plus every generated token."""
        worst = pending.req.prompt_len + pending.req.max_new_tokens
        padded = round_up(worst, self.ecfg.prefill_chunk)
        return pages_needed(padded, self.ecfg.page_size)

    def submit(self, req: Request, now: float) -> RequestResult:
        result = RequestResult(
            req_id=req.req_id, prompt_len=req.prompt_len,
            t_arrival=req.arrival_time if req.arrival_time is not None
            else now)
        pending = _Pending(req, result, [])
        need = self._required_pages(pending)
        if need > min(self.ecfg.max_pages_per_req, self.pool.pages_total - 1):
            raise ValueError(
                f"request {req.req_id} needs {need} pages "
                f"(prompt {req.prompt_len} + gen {req.max_new_tokens}) but the "
                f"engine caps at min(max_pages_per_req="
                f"{self.ecfg.max_pages_per_req}, pool="
                f"{self.pool.pages_total - 1})")
        self.waiting.append(pending)
        return result

    # --------------------------------------------------------- admission ----
    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, now: float) -> int:
        """FCFS: admit from the queue head while a slot + pages exist."""
        n = 0
        while self.waiting:
            slot_id = self._free_slot()
            if slot_id is None:
                break
            pending = self.waiting[0]
            padded = round_up(pending.ext_len, self.ecfg.prefill_chunk)
            pages = self.pool.alloc(pages_needed(padded, self.ecfg.page_size))
            if pages is None:
                break                        # head blocks (strict FCFS)
            self.waiting.popleft()
            if pending.result.t_admitted != pending.result.t_admitted:  # nan
                pending.result.t_admitted = now
            self.slots[slot_id] = SlotState(
                slot=slot_id, req=pending.req, result=pending.result,
                generated=pending.generated, pages=pages,
                admit_seq=self._admit_seq, prefill_target=pending.ext_len)
            self._admit_seq += 1
            n += 1
        return n

    # -------------------------------------------------------- preemption ----
    def _preempt(self, slot: SlotState, now: float) -> None:
        """Release everything; resume later from prompt + generated."""
        self.pool.free(slot.pages)
        self.slots[slot.slot] = None
        slot.result.n_preemptions += 1
        self.n_preemptions += 1
        self.waiting.appendleft(_Pending(slot.req, slot.result,
                                         list(slot.generated)))

    def _preempt_youngest(self, exclude, now: float) -> bool:
        victims = [s for s in self.slots
                   if s is not None and s is not exclude]
        if not victims:
            return False
        self._preempt(max(victims, key=lambda s: s.admit_seq), now)
        return True

    def _ensure_decode_page(self, slot: SlotState, now: float) -> bool:
        """Make sure the page holding write-slot ``cache_len`` exists. May
        preempt younger requests — or ``slot`` itself if it is the youngest
        and the pool is dry. Returns False if ``slot`` was preempted."""
        need_idx = slot.cache_len // self.ecfg.page_size
        while need_idx >= len(slot.pages):
            got = self.pool.alloc(1)
            if got is not None:
                slot.pages.extend(got)
                continue
            if not self._preempt_youngest(exclude=slot, now=now):
                self._preempt(slot, now)     # youngest itself: requeue whole
                return False
        return True

    # ----------------------------------------------------------- packing ----
    def _tick_budget(self) -> float:
        if self.ecfg.tick_work_budget is not None:
            return self.ecfg.tick_work_budget
        e = self.ecfg
        return (e.max_running * chunk_token_work(1, e.max_model_len)
                + e.prefill_slots * chunk_token_work(e.prefill_chunk,
                                                     e.max_model_len))

    def plan_tick(self, now: float) -> TickPlan:
        budget = self._tick_budget()
        prefill_pending = sorted(
            (s for s in self.slots if s is not None and s.phase == "prefill"),
            key=lambda s: s.admit_seq)

        # decode set: oldest first so growth steals from the youngest
        decode = []
        if self.ecfg.mixed or not prefill_pending:
            for s in sorted((s for s in self.slots
                             if s is not None and s.phase == "decode"),
                            key=lambda s: s.admit_seq):
                if self.slots[s.slot] is not s:
                    continue             # preempted by an older slot's growth
                if self._ensure_decode_page(s, now):
                    decode.append(s)
            # growth can also preempt slots appended *earlier* in this loop
            decode = [s for s in decode if self.slots[s.slot] is s]
        work = sum(chunk_token_work(1, s.cache_len) for s in decode)

        # prefill chunks ride along FCFS under the remaining budget
        prefill = []
        C = self.ecfg.prefill_chunk
        for s in prefill_pending:
            if self.slots[s.slot] is not s:
                continue                     # preempted by decode growth
            if len(prefill) >= self.ecfg.prefill_slots:
                break
            start = s.prefill_done
            n_real = min(C, s.prefill_target - start)
            w = chunk_token_work(n_real, start)
            if work + w > budget and (prefill or decode):
                break                        # budget spent; keep FCFS order
            prefill.append((s, start, n_real))
            work += w
        return TickPlan(decode=decode, prefill=prefill)

    # ------------------------------------------------------- tick commit ----
    def _emit(self, slot: SlotState, token: int, now: float) -> None:
        if not slot.generated:
            slot.result.t_first_token = now
        slot.generated.append(token)
        slot.result.tokens.append(token)
        if slot.req.on_token is not None:
            slot.req.on_token(slot.req.req_id, token)
        if len(slot.generated) >= slot.req.max_new_tokens:
            slot.result.t_finish = now
            self.pool.free(slot.pages)
            self.slots[slot.slot] = None
            self.finished.append(slot.result)

    def commit_decode(self, slot: SlotState, token: int, now: float) -> None:
        slot._decoded += 1
        self._emit(slot, token, now)

    def commit_prefill(self, slot: SlotState, start: int, n_real: int,
                       next_token: int, now: float) -> None:
        slot.prefill_done = start + n_real
        if slot.prefill_done >= slot.prefill_target:
            slot.phase = "decode"
            self._emit(slot, next_token, now)   # final chunk's greedy token

    # ------------------------------------------------------------- state ----
    @property
    def n_running(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def idle(self) -> bool:
        return not self.waiting and self.n_running == 0
