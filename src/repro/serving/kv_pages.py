"""Paged KV-cache allocator — the StateStore page layout made dynamic.

The device pool (`models.decode.init_paged_cache`) is a fixed array of
``pages_total`` pages of ``page_size`` KV slots each; this module owns the
*host-side* free list that maps requests onto it. Geometry (which page/offset
a token lives at) is `core.statestore.pages_needed`/`page_slot` — shared with
the kernels so scheduler, allocator and attention agree by construction.

Page 0 is reserved as the NULL page: it is never allocated, padded
page-table entries and inactive batch slots point at it, and the engine
routes all masked/garbage writes there. Peak real usage is therefore bounded
by ``pages_total - 1`` pages — the serving counterpart of ChunkFlow's
"memory bounded by chunk size, not sequence length".
"""
from __future__ import annotations

from collections import deque

from repro.core.statestore import pages_needed  # noqa: F401  (re-export)

NULL_PAGE = 0


class PagePool:
    """Free-list allocator over the device pool's page indices.

    alloc() is all-or-nothing: a request either gets every page it asked for
    or None (the scheduler then queues or preempts) — pages are never
    oversubscribed and never handed out twice.
    """

    def __init__(self, pages_total: int):
        assert pages_total >= 2, "need at least the null page + one real page"
        self.pages_total = pages_total
        self._free = deque(range(1, pages_total))
        self._held = set()
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._held)

    def alloc(self, n: int):
        """-> list of ``n`` page ids, or None if the pool can't satisfy it."""
        if n < 0 or n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._held.update(pages)
        self.peak_in_use = max(self.peak_in_use, len(self._held))
        return pages

    def free(self, pages) -> None:
        for p in pages:
            assert p in self._held, f"double free / foreign page {p}"
            self._held.discard(p)
            self._free.append(p)
