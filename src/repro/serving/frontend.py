"""Serving frontend: request/response types + arrival simulation.

Requests carry an optional streaming callback ``on_token(req_id, token)``
fired as each greedy token materialises on the host. Arrival processes:

  * `trace_requests`  — fixed (lengths, arrival_times) traces, the
                        reproducible input for equivalence tests;
  * `poisson_requests`— Poisson arrivals with prompt lengths drawn from the
                        paper's long-tail CDFs via the shared
                        `core.chunking.sample_lengths` helper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np

from repro.core.chunking import sample_lengths


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray                       # (T,) int32 token ids
    max_new_tokens: int
    arrival_time: Optional[float] = None     # None = "when submitted"
    on_token: Optional[Callable[[int, int], None]] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size >= 1, "empty prompt"
        assert self.max_new_tokens >= 1

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


@dataclasses.dataclass
class RequestResult:
    req_id: int
    prompt_len: int
    t_arrival: float
    tokens: list = dataclasses.field(default_factory=list)
    t_admitted: float = math.nan
    t_first_token: float = math.nan
    t_finish: float = math.nan
    n_preemptions: int = 0

    @property
    def done(self) -> bool:
        return not math.isnan(self.t_finish)

    @property
    def ttft(self) -> float:
        """Time to first token (arrival -> first generated token)."""
        return self.t_first_token - self.t_arrival

    @property
    def e2e_latency(self) -> float:
        return self.t_finish - self.t_arrival


def trace_requests(lengths, *, vocab_size: int, max_new_tokens: int = 16,
                   arrival_times=None, seed: int = 0,
                   on_token=None) -> list:
    """Fixed trace: one request per entry of ``lengths``. Deterministic
    prompts (seeded), arrivals default to all-at-once at t=0."""
    rng = np.random.RandomState(seed)
    if arrival_times is None:
        arrival_times = [0.0] * len(lengths)
    assert len(arrival_times) == len(lengths)
    return [
        Request(req_id=i,
                prompt=rng.randint(1, vocab_size, size=int(l)).astype(np.int32),
                max_new_tokens=max_new_tokens,
                arrival_time=float(t), on_token=on_token)
        for i, (l, t) in enumerate(zip(lengths, arrival_times))
    ]


def poisson_requests(n: int, rate: float, *, vocab_size: int,
                     dist="paper_eval", seed: int = 0,
                     max_new_tokens: int = 16, min_len: int = 16,
                     max_prompt: Optional[int] = None,
                     on_token=None) -> list:
    """``n`` requests with exponential inter-arrival gaps (``rate`` req/s of
    simulated time) and long-tail prompt lengths from the paper's CDFs."""
    assert rate > 0
    lengths = sample_lengths(dist, n, seed, min_len=min_len,
                             max_len=max_prompt)
    rng = np.random.RandomState(seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return trace_requests(lengths, vocab_size=vocab_size,
                          max_new_tokens=max_new_tokens,
                          arrival_times=arrivals.tolist(), seed=seed + 2,
                          on_token=on_token)


def latency_percentiles(results, pcts=(50, 99)) -> dict:
    """Summarise finished RequestResults -> {metric: {p50: ..., p99: ...}}."""
    done = [r for r in results if r.done]
    out = {"n_done": len(done)}
    for name, vals in [("ttft", [r.ttft for r in done]),
                       ("e2e", [r.e2e_latency for r in done])]:
        out[name] = {f"p{p}": float(np.percentile(vals, p)) if done else None
                     for p in pcts}
    return out
