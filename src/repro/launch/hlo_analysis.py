"""Loop-aware HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` visits each while-loop body ONCE, so any
program built from ``lax.scan`` (layer stacks, grad accumulation, blockwise
attention) under-counts FLOPs / bytes / collective traffic by the loop trip
counts. This module re-derives the three roofline inputs from
``compiled.as_text()`` with multipliers:

  * computations graph: fusion ``calls=``, while ``body=/condition=``,
    ``to_apply=``, conditional branches;
  * while trip counts parsed from the condition's ``compare(iter, constant)``;
  * multiplier(comp) = sum over callers of mult(caller) * trips(if while body);
  * FLOPs: 2 * prod(result_dims) * contraction_size per dot (any computation);
  * collective bytes: result-shape bytes per collective op (per-device HLO,
    post-SPMD) — a consistent per-device traffic proxy;
  * HBM bytes: operand+result bytes of top-level (non-fused) ops.

Known approximations are documented in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import collections
import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) (?:\([^)]*\))? ?->", re.M)
_LHS_RE = re.compile(r"^\s*(?:ROOT )?%?([\w\.\-]+) = ")
# first lowercase identifier followed by '(' on the RHS is the opcode — HLO
# type strings (tuples, layouts, /*index=N*/ comments) never contain one
_OPCODE_RE = re.compile(r"([a-z][a-zA-Z0-9\-]*)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _result_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str
    line: str


def parse_module(text: str):
    """-> (comps: {name: [Op]}, shapes: {op_name: type_str})"""
    comps, shapes = {}, {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith(("//", "#")):
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*[\(]", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is None:
            continue
        lm = _LHS_RE.match(line)
        if not lm:
            continue
        name = lm.group(1)
        rhs = line[lm.end():]
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        type_str = rhs[:om.start()].strip()
        opcode = om.group(1)
        # operand list: scan to the matching close paren
        depth, i = 0, om.end() - 1
        while i < len(rhs):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        operands_str = rhs[om.end(): i]
        attrs = rhs[i + 1:]
        operands = re.findall(r"%([\w\.\-]+)", operands_str)
        op = Op(name, type_str, opcode, operands, attrs, line)
        comps[cur].append(op)
        shapes[name] = type_str
    return comps, shapes


def _trip_count(cond_ops, comps):
    """Trip count of a while condition: the loop bound constant compared
    against the induction variable. The compare may sit inside a fusion
    called from the condition, so we look one level down too."""
    consts = []
    le = False
    stack = list(cond_ops)
    seen = set()
    while stack:
        op = stack.pop()
        cm = _CONST_RE.search(op.line)
        if op.opcode == "constant" and cm:
            consts.append(int(cm.group(1)))
        if op.opcode == "compare" and "direction=LE" in op.attrs:
            le = True
        for m in _CALL_ATTR_RE.finditer(op.attrs):
            callee = m.group(1)
            if callee in comps and callee not in seen:
                seen.add(callee)
                stack.extend(comps[callee])
    if not consts:
        return 1
    n = max(consts)
    return max(n + (1 if le else 0), 1)


def computation_multipliers(comps):
    """multiplier per computation, composing nested while trip counts."""
    # edges: caller -> [(callee, factor)]
    edges = collections.defaultdict(list)
    trip_cache = {}
    for cname, ops in comps.items():
        for op in ops:
            if op.opcode == "while":
                body = cond = None
                for m in _CALL_ATTR_RE.finditer(op.attrs):
                    kind = m.group(0).split("=")[0]
                    if kind == "body":
                        body = m.group(1)
                    elif kind == "condition":
                        cond = m.group(1)
                if body and cond and cond in comps:
                    trips = trip_cache.setdefault(
                        cond, _trip_count(comps[cond], comps))
                    edges[cname].append((body, trips))
                    edges[cname].append((cond, trips + 1))
            else:
                for m in _CALL_ATTR_RE.finditer(op.attrs):
                    callee = m.group(1)
                    if callee in comps:
                        edges[cname].append((callee, 1))
                bm = _BRANCHES_RE.search(op.attrs)
                if bm:
                    for callee in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        if callee in comps:
                            edges[cname].append((callee, 1))

    entry = None
    callees = {c for outs in edges.values() for c, _ in outs}
    for c in comps:
        if c not in callees:
            entry = c if entry is None or "main" in c else entry
    mult = collections.defaultdict(float)
    mult[entry] = 1.0
    # topological propagation (call graph is a DAG)
    order = []
    seen = set()

    def visit(c):
        if c in seen:
            return
        seen.add(c)
        for callee, _ in edges.get(c, ()):  # post-order
            visit(callee)
        order.append(c)

    visit(entry)
    for c in reversed(order):
        for callee, f in edges.get(c, ()):
            mult[callee] += mult[c] * f
    return dict(mult), entry


def analyze(text: str) -> dict:
    comps, shapes = parse_module(text)
    mult, entry = computation_multipliers(comps)

    flops = 0.0
    coll = {c: 0.0 for c in COLLECTIVES}
    coll_counts = {c: 0.0 for c in COLLECTIVES}
    hbm_bytes = 0.0
    fused = set()
    for _cname, ops in comps.items():
        for op in ops:
            if op.opcode == "fusion":
                for m in _CALL_ATTR_RE.finditer(op.attrs):
                    fused.add(m.group(1))

    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        top_level = cname not in fused
        for op in ops:
            if op.opcode == "dot":
                _, rdims = _result_dims(op.type_str)
                lhs_shape = shapes.get(op.operands[0], "")
                _, ldims = _result_dims(lhs_shape)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                  op.attrs)
                csize = 1
                if cdims and ldims:
                    for i in cdims.group(1).split(","):
                        if i:
                            csize *= ldims[int(i)]
                f = 2.0
                for d in rdims:
                    f *= d
                flops += f * csize * m
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                coll[base] += _shape_bytes(op.type_str) * m
                coll_counts[base] += m
            if top_level and op.opcode not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional"):
                b = _shape_bytes(op.type_str)
                for o in op.operands:
                    b += _shape_bytes(shapes.get(o, ""))
                hbm_bytes += b * m

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll,
        "collective_counts": coll_counts,
        "collective_total": sum(coll.values()),
        "entry": entry,
        "n_computations": len(comps),
    }
