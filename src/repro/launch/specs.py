"""ShapeDtypeStruct input specs + step builders for the multi-pod dry-run.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable stand-ins for
every model input — no device allocation ever happens; the dry-run lowers
against these and ``.compile()`` proves the distribution config is coherent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding
from repro.models import api, decode
from repro.optim import adamw, adafactor

BF16 = jnp.bfloat16
I32 = jnp.int32

# archs whose AdamW fp32 states cannot fit a v5e pod (DESIGN.md §6)
ADAFACTOR_ARCHS = {"kimi-k2-1t-a32b", "jamba-1.5-large-398b", "yi-34b",
                   "qwen2.5-72b"}


def microbatch_rows(cfg: ModelConfig, shape: InputShape) -> int:
    """Rows per grad-accumulation microbatch (multiple of the widest DP=32).

    Fewer microbatches -> fewer FSDP weight re-gathers (they repeat every
    microbatch pass; §Perf iteration 4 measured -46% collective on
    qwen2.5-14b). MoE/hybrid archs keep smaller microbatches — their dispatch
    buffers scale with tokens per microbatch and dominate peak memory."""
    if cfg.num_experts:
        return min(shape.global_batch, 32)
    return min(shape.global_batch, 64)


def model_inputs(cfg: ModelConfig, B: int, T: int, *, for_train: bool):
    s = {"tokens": jax.ShapeDtypeStruct((B, T), I32)}
    if for_train:
        s["labels"] = jax.ShapeDtypeStruct((B, T), I32)
    if cfg.family == "vlm":
        s["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), BF16)
    if cfg.family == "audio":
        s["encoder_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), BF16)
    return s


def params_shape(cfg: ModelConfig, max_seq: int):
    return jax.eval_shape(
        lambda k: api.init_params(cfg, k, max_seq=max_seq),
        jax.random.PRNGKey(0))


def _total_params(pshape) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(pshape))


def fsdp_threshold(cfg: ModelConfig, pshape, mesh, *, training: bool) -> int:
    """FSDP (ZeRO-3 over 'data') only when the TP-sharded state cannot fit a
    16 GB v5e chip: training counts params+grads+optimizer (~14 B/param with
    AdamW, ~6 with Adafactor+bf16 accum), inference counts bf16 params only.
    Below that, re-gathering weights every layer/microbatch is pure
    collective waste (§Perf iterations 1-2)."""
    msz = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    n = _total_params(pshape)
    if training:
        per_param = 6 if cfg.name in ADAFACTOR_ARCHS else 14
    else:
        per_param = 2
    per_chip = n * per_param / msz
    if per_chip > 12e9:
        return sharding.FSDP_THRESHOLD
    return 1 << 60          # effectively disables FSDP


def opt_shape(cfg: ModelConfig, pshape, arch_name: str):
    if arch_name in ADAFACTOR_ARCHS:
        return jax.eval_shape(adafactor.adafactor_init, pshape)
    return jax.eval_shape(adamw.adamw_init, pshape)


def input_specs(cfg: ModelConfig, shape: InputShape, mesh):
    """-> (arg_structs tuple, in_shardings tuple, step_fn) for the shape kind."""
    import dataclasses
    msz = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if cfg.num_heads and cfg.num_heads % msz:
        # pad head counts to the TP width so attention shards (§Perf iter 3)
        cfg = dataclasses.replace(cfg, pad_heads_to=msz)
    if shape.kind == "train":
        return _train_specs(cfg, shape, mesh)
    if shape.kind == "prefill":
        return _prefill_specs(cfg, shape, mesh)
    return _decode_specs(cfg, shape, mesh)


# ----------------------------------------------------------------- train ----
def make_train_step(cfg: ModelConfig, shape: InputShape, arch_name: str,
                    *, blockwise_threshold: int = 2048, dp=("data",),
                    model_size: int = 16, mesh=None):
    m = microbatch_rows(cfg, shape)
    nmb = shape.global_batch // m
    use_adafactor = arch_name in ADAFACTOR_ARCHS
    accum_dtype = jnp.bfloat16 if use_adafactor else jnp.float32
    total_tokens = shape.global_batch * shape.seq_len

    from repro.models.layers import batch_sharding

    def mb_loss(p, mb):
        mb = jax.tree.map(lambda x: jax.lax.with_sharding_constraint(
            x, P(dp, *([None] * (x.ndim - 1)))), mb)
        with batch_sharding(dp, model_size, mesh=mesh):
            logits, _, aux = api.forward(
                cfg, p, mb, remat=True,
                blockwise_threshold=blockwise_threshold)
        # keep logits vocab-sharded through the loss (Megatron vocab-parallel
        # cross entropy: lse reduce + label gather stay distributed)
        logits = jax.lax.with_sharding_constraint(
            logits, P(dp, None, "model"))
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, mb["labels"][..., None], axis=-1)[..., 0]
        return nll.sum() / total_tokens + aux["moe_aux"] / nmb

    def train_step(params, opt_state, batch):
        def reshape(x):
            x = x.reshape(nmb, m, *x.shape[1:])
            return jax.lax.with_sharding_constraint(
                x, P(None, dp, *([None] * (x.ndim - 2))))
        mbs = jax.tree.map(reshape, batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)

        def body(carry, mb):
            gacc, lacc = carry
            l, g = jax.value_and_grad(mb_loss)(params, mb)
            gacc = jax.tree.map(lambda a, b: a + b.astype(accum_dtype),
                                gacc, g)
            return (gacc, lacc + l), None

        (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mbs)
        if use_adafactor:
            new_params, new_opt = adafactor.adafactor_update(
                params, grads, opt_state, lr=1e-4)
        else:
            new_params, new_opt, _ = adamw.adamw_update(
                params, grads, opt_state, lr=1e-4)
        return new_params, new_opt, loss

    return train_step, nmb


def _train_specs(cfg, shape, mesh):
    pshape = params_shape(cfg, max_seq=shape.seq_len)
    oshape = opt_shape(cfg, pshape, cfg.name)
    thr = fsdp_threshold(cfg, pshape, mesh, training=True)
    pspecs = sharding.param_specs(cfg, pshape, mesh, fsdp_threshold=thr)
    if cfg.name in ADAFACTOR_ARCHS:
        ospecs = sharding.adafactor_opt_specs(pspecs, pshape)
    else:
        ospecs = sharding.adamw_opt_specs(pspecs)
    binputs = model_inputs(cfg, shape.global_batch, shape.seq_len,
                           for_train=True)
    bspecs = sharding.batch_specs(cfg, binputs, mesh)
    step, _ = make_train_step(cfg, shape, cfg.name,
                              dp=sharding.dp_axes(mesh), mesh=mesh)
    to_sharding = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    args = (pshape, oshape, binputs)
    shardings = (to_sharding(pspecs), to_sharding(ospecs), to_sharding(bspecs))
    return args, shardings, step


# --------------------------------------------------------------- prefill ----
def _prefill_specs(cfg, shape, mesh):
    pshape = params_shape(cfg, max_seq=shape.seq_len)
    thr = fsdp_threshold(cfg, pshape, mesh, training=False)
    pspecs = sharding.param_specs(cfg, pshape, mesh, fsdp_threshold=thr)
    binputs = model_inputs(cfg, shape.global_batch, shape.seq_len,
                           for_train=False)
    bspecs = sharding.batch_specs(cfg, binputs, mesh)

    def prefill_step(params, batch):
        logits, state, _ = api.forward(cfg, params, batch,
                                       blockwise_threshold=4096)
        return logits[:, -1:], state

    to_sharding = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    return ((pshape, binputs),
            (to_sharding(pspecs), to_sharding(bspecs)), prefill_step)


# ---------------------------------------------------------------- decode ----
def _decode_specs(cfg, shape, mesh):
    B, S = shape.global_batch, shape.seq_len
    pshape = params_shape(cfg, max_seq=S)
    thr = fsdp_threshold(cfg, pshape, mesh, training=False)
    pspecs = sharding.param_specs(cfg, pshape, mesh, fsdp_threshold=thr)
    # sliding-window ring cache for local/global archs at long context
    # (§Perf: halves gemma2's 500K cache — local layers hold W slots)
    ring = bool(cfg.local_global_alternate and cfg.sliding_window
                and S >= 131_072)
    cshape = jax.eval_shape(
        lambda: decode.init_decode_cache(cfg, B, S, dtype=BF16,
                                         ring_local=ring))
    cspecs = sharding.cache_specs(cfg, cshape, mesh, B)
    tok = jax.ShapeDtypeStruct((B, 1), I32)
    clen = jax.ShapeDtypeStruct((), I32)

    def serve_step(params, cache, tokens, cache_len):
        return decode.decode_step(cfg, params, cache, tokens, cache_len)

    to_sharding = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))
    tok_spec = sharding.batch_specs(cfg, {"tokens": tok}, mesh)["tokens"]
    return ((pshape, cshape, tok, clen),
            (to_sharding(pspecs), to_sharding(cspecs),
             NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())),
            serve_step)
