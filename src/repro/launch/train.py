"""End-to-end ChunkFlow fine-tuning driver (paper Fig. 3 workflow).

Each iteration: sample a long-tail batch -> Algorithm 1 chunk construction
(on a background prefetch thread, overlapped with device compute) ->
Algorithm 2 state-aware scheduling (gradients accumulate across chunks &
groups; with --dp N the dp_balance planner spreads chunk groups across a
data mesh axis and GSPMD psums the gradients; with --pp S the same plan
runs on a 2D data x pipe mesh through the K-retention rotation pipeline) ->
one optimizer step with donated param/grad/opt buffers. Mathematically
equivalent to full-sequence training (tests/test_chunked_equivalence.py,
tests/test_dp_balance.py, tests/test_pipeline2d.py), with peak activation
memory bounded by K * ChunkSize tokens per rank (per stage under --pp).

CPU-scale entry points (the multi-pod path is exercised by launch/dryrun.py):

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 20 --chunk-size 256 --k 1 --reduced

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 5 --chunk-size 256 --k 1 --reduced --dp 4

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --steps 5 --chunk-size 256 --retain-k 2 --reduced --dp 2 --pp 2

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
        --steps 5 --chunk-size 256 --reduced --dp 2 --pp 2 --cp 2
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core import chunked_step, chunking, planner, tuning
from repro.data.prefetch import Prefetcher, synchronous
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF
from repro.distributed import sharding
from repro.launch import mesh as mesh_lib
from repro.models import api
from repro.optim import adamw
from repro.checkpoint.io import restore_checkpoint, save_checkpoint


def build_host_batches(seqs, lengths, chunk_size):
    """Algorithm 1 on the host: chunk construction + materialization into
    padded numpy arrays. Pure numpy — safe to run on the prefetch thread."""
    chunks = chunking.construct_chunks(lengths, chunk_size)
    groups, standalone = chunking.group_chunks(chunks)
    gb = [[chunking.materialize_chunk(c, seqs) for c in g]
          for g in groups.values()]
    sb = [chunking.materialize_chunk(c, seqs) for c in standalone]
    return gb, sb, chunks


def _to_device(gb, sb):
    to_dev = lambda m: {k: jnp.asarray(v) for k, v in m.items()}
    return [[to_dev(b) for b in g] for g in gb], [to_dev(b) for b in sb]


def train(cfg, tc: TrainConfig, *, batch_per_step: int = 8,
          max_len: int = 2048, log_every: int = 1, checkpoint_path=None,
          sampler=None, mesh=None, prefetch_depth: int = 2,
          plan_policy: str = "solve", cp_threshold: int = 0,
          resume_path=None, ring_overlap: bool = True,
          offload_statestore: bool = False, store_prefetch_depth: int = 2):
    params = api.init_params(cfg, jax.random.PRNGKey(tc.seed),
                             max_seq=max_len + 8)
    opt_state = adamw.adamw_init(params)
    sampler = sampler or LongTailSampler(PAPER_EVAL_CDF, min_len=32,
                                         seed=tc.seed, max_len=max_len)
    start_step = 0
    if resume_path:
        # restore BEFORE mesh placement: the pipeline_put/replicate_put
        # below then shards the restored state exactly like a fresh run
        restored, start_step = restore_checkpoint(
            resume_path, {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        # replay the sampler past the consumed steps so the resumed stream
        # continues where the interrupted run left off (save->resume->step
        # is bit-compatible with the uninterrupted run)
        for _ in range(start_step):
            sampler.sample_batch(batch_per_step, cfg.vocab_size)
        print(f"resumed step {start_step} <- {resume_path}")
    dp = sharding.dp_size(mesh) if mesh is not None else 1
    pp = sharding.pipe_size(mesh)
    cp = sharding.seq_size(mesh)
    if pp > 1:
        # stage-sharded layer slabs over "pipe", everything else replicated;
        # adamw m/v are param-shaped so they inherit the same placement
        params = sharding.pipeline_put(mesh, params)
        opt_state = sharding.pipeline_put(mesh, opt_state)
    elif dp > 1 or cp > 1:
        # keep train state resident on the mesh (replicated) across steps so
        # run_batch/apply_update never re-transfer it
        params = sharding.replicate_put(mesh, params)
        opt_state = sharding.replicate_put(mesh, opt_state)

    # donate params + opt state: adamw aliases them 1:1 into the outputs, so
    # the optimizer step is in-place on device (grads have no aliasable
    # output — donating them only buys a warning)
    @functools.partial(jax.jit, donate_argnums=(0, 2))
    def apply_update(params, grads, opt_state, lr):
        return adamw.adamw_update(params, grads, opt_state, lr=lr,
                                  weight_decay=tc.weight_decay,
                                  grad_clip=tc.grad_clip)

    def produce(step):
        seqs, lengths = sampler.sample_batch(batch_per_step, cfg.vocab_size)
        return build_host_batches(seqs, lengths, tc.chunk_size)

    n_steps = tc.total_steps - start_step
    stream = (Prefetcher(produce, n_steps, depth=prefetch_depth)
              if prefetch_depth > 0 else synchronous(produce, n_steps))

    history = []
    try:
        for off, (gb_h, sb_h, chunks) in enumerate(stream):
            step = start_step + off
            t0 = time.time()
            # Mesh paths consume host batches directly: the planner reads
            # token counts without device round-trips, and wave_put transfers
            # each stacked wave slot straight to its sharded layout (no
            # staging copy on the default device)
            gb, sb = (gb_h, sb_h) if (dp > 1 or pp > 1 or cp > 1) \
                else _to_device(gb_h, sb_h)
            # mesh=None gets an explicit trivial plan too (not None): the
            # bare plan=None default is k=1, which would silently drop --k
            # (and the offload/overlap knobs) on the single-device path
            plan = (planner.plan_batch(gb, sb, mesh, k=tc.k_chunks,
                                       policy=plan_policy,
                                       cp_threshold=cp_threshold,
                                       ring_overlap=ring_overlap,
                                       offload_statestore=offload_statestore,
                                       prefetch_depth=store_prefetch_depth)
                    if mesh is not None else
                    planner.ExecutionPlan(
                        data=1, pipe=1, seq=1, chunk_size=tc.chunk_size,
                        k=tc.k_chunks, waves=[], ring_overlap=ring_overlap,
                        offload_statestore=offload_statestore,
                        prefetch_depth=store_prefetch_depth))
            loss, grads, stats = chunked_step.run_batch(
                cfg, params, (gb, sb), plan)
            lr = adamw.cosine_schedule(step, base_lr=tc.learning_rate,
                                       warmup_steps=tc.warmup_steps,
                                       total_steps=tc.total_steps)
            params, opt_state, gnorm = apply_update(params, grads, opt_state,
                                                    lr)
            dt = time.time() - t0
            history.append({
                "step": step, "loss": float(loss), "gnorm": float(gnorm),
                "sec": dt, "n_chunks": len(chunks),
                "n_groups": len(gb), "recomputes": stats.recompute_calls,
                "peak_residuals": stats.max_live_residuals,
            })
            if pp > 1:
                history[-1]["bubble_ratio"] = stats.bubble_ratio
            if cp > 1:
                history[-1]["ring_steps"] = stats.ring_steps
                history[-1]["overlapped_hops"] = stats.overlapped_hops
            if offload_statestore and hasattr(stats,
                                              "resident_statestore_bytes"):
                history[-1]["store_device_bytes"] = \
                    stats.resident_statestore_bytes
                history[-1]["store_host_bytes"] = \
                    stats.offloaded_statestore_bytes
                history[-1]["store_prefetches"] = stats.statestore_prefetches
            if step % log_every == 0:
                h = history[-1]
                print(f"step {step:4d} loss {h['loss']:.4f}"
                      f" gnorm {h['gnorm']:.3f}"
                      f" chunks {h['n_chunks']:3d} (groups {h['n_groups']})"
                      f" recompute {h['recomputes']} {dt:.2f}s"
                      + (f" dp {dp}" if dp > 1 else "")
                      + (f" pp {pp} bubble {stats.bubble_ratio:.0%}"
                         if pp > 1 else "")
                      + (f" cp {cp} ring {stats.ring_steps}"
                         if cp > 1 else ""))
    finally:
        if hasattr(stream, "close"):
            stream.close()
    if checkpoint_path:
        save_checkpoint(checkpoint_path,
                        {"params": params, "opt": opt_state},
                        step=tc.total_steps)
        print(f"checkpoint -> {checkpoint_path}")
    return params, opt_state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--chunk-size", type=int, default=256)
    ap.add_argument("--k", "--retain-k", type=int, default=1, dest="k",
                    help="Algorithm 2 K: chunk states retained for backward "
                         "(per stage when --pp > 1); first N-K chunks of a "
                         "group are recomputed")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel degree; needs >= dp visible devices "
                         "(CPU: XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages; composes with --dp on a 2D "
                         "(data x pipe) mesh of dp*pp devices (num_layers "
                         "must divide by pp)")
    ap.add_argument("--cp", type=int, default=1,
                    help="context-parallel degree: chunk tokens shard over "
                         "a \"seq\" mesh axis and K/V circulates as a "
                         "ppermute ring (removes the one-device ChunkSize "
                         "cap); composes with --dp/--pp on a dp*pp*cp-device "
                         "mesh (chunk-size must divide by cp)")
    ap.add_argument("--cp-threshold", type=int, default=0,
                    help="minimum unit token span (chunks * ChunkSize) that "
                         "rides the CP ring; shorter units replicate over "
                         "\"seq\" instead of paying ring latency (0 = all)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint path to restore params/opt state/step "
                         "from; continues an interrupted run (the data "
                         "stream is replayed to the restored step)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host-side prefetch depth (0 = synchronous)")
    ap.add_argument("--ring-overlap", type=int, default=1,
                    help="1 (default): double-buffer the cp ring — hop i+1's "
                         "K/V ppermute issued under hop i's flash kernel, in "
                         "forward and backward (numerically identical); "
                         "0: serial ring (debug / A-B timing)")
    ap.add_argument("--offload-statestore", action="store_true",
                    help="host-offload cold StateStore prefix versions: only "
                         "the latest capacity buffer stays device-resident; "
                         "written C-slot buckets mirror to (pinned, where "
                         "available) host memory and stream back on the "
                         "planner's prefetch schedule for the F2 re-reads")
    ap.add_argument("--store-prefetch", type=int, default=2,
                    help="StateStore host->device prefetch depth: buckets "
                         "kept in flight ahead of the F2 reassembly writes")
    ap.add_argument("--plan", default="solve",
                    choices=("solve", "lpt", "round_robin"),
                    help="wave planning policy: 'solve' = heterogeneous "
                         "per-wave cp planner (core/planner.py); "
                         "'lpt'/'round_robin' = fixed global cp with the "
                         "legacy dp_balance assignment")
    ap.add_argument("--tune", action="store_true",
                    help="run the launch-config grid search (tuning"
                         ".grid_search over dp*pp*cp devices, heterogeneous "
                         "plans included), print the ranked table and exit")
    ap.add_argument("--tune-launch", action="store_true",
                    help="after --tune, launch training with the top-ranked "
                         "config (its mesh/C/K override the CLI values)")
    ap.add_argument("--tune-budget", type=int, default=32768,
                    help="K*ChunkSize live-activation token budget for "
                         "--tune candidates")
    ap.add_argument("--tune-chunk-sizes", default=None,
                    help="comma-separated ChunkSize candidates for --tune "
                         "(default: the grid_search defaults)")
    ap.add_argument("--tune-ks", default=None,
                    help="comma-separated K candidates for --tune")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(chunk_size=args.chunk_size, k_chunks=args.k,
                     learning_rate=args.lr, total_steps=args.steps)

    if args.tune or args.tune_launch:
        top = _tune(args, cfg, tc)
        if not args.tune_launch:
            return
        tc = TrainConfig(chunk_size=top.chunk_size, k_chunks=top.k,
                         learning_rate=args.lr, total_steps=args.steps)
        mesh = mesh_lib.mesh_for_config(top)
        print(f"launching top config: {top.describe()}")
        train(cfg, tc, batch_per_step=args.batch, max_len=args.max_len,
              checkpoint_path=args.checkpoint, mesh=mesh,
              prefetch_depth=args.prefetch, plan_policy="solve",
              resume_path=args.resume)
        return

    if args.cp > 1 and args.chunk_size % args.cp:
        raise SystemExit(f"--chunk-size {args.chunk_size} must divide by "
                         f"--cp {args.cp}")
    if args.pp > 1 or args.cp > 1:
        mesh = mesh_lib.make_train_mesh(args.dp, args.pp, args.cp)
    elif args.dp > 1:
        mesh = mesh_lib.make_data_mesh(args.dp)
    else:
        mesh = None
    train(cfg, tc, batch_per_step=args.batch, max_len=args.max_len,
          checkpoint_path=args.checkpoint, mesh=mesh,
          prefetch_depth=args.prefetch, plan_policy=args.plan,
          cp_threshold=args.cp_threshold, resume_path=args.resume,
          ring_overlap=bool(args.ring_overlap),
          offload_statestore=args.offload_statestore,
          store_prefetch_depth=args.store_prefetch)


def _tune(args, cfg, tc):
    """--tune: grid-search full launch configs (fixed AND solved
    heterogeneous) on sampled long-tail batches, print the ranked table,
    return the top LaunchConfig."""
    world = args.dp * args.pp * args.cp
    if world <= 1:
        world = max(1, len(jax.devices()))
    sampler = LongTailSampler(PAPER_EVAL_CDF, min_len=32, seed=tc.seed,
                              max_len=args.max_len)
    batches = []
    for _ in range(4):
        _, lengths = sampler.sample_batch(args.batch, cfg.vocab_size)
        batches.append(lengths)
    csv_int = lambda s: tuple(int(x) for x in s.split(",") if x)
    kw = {}
    if args.tune_chunk_sizes:
        kw["chunk_sizes"] = csv_int(args.tune_chunk_sizes)
    if args.tune_ks:
        kw["ks"] = csv_int(args.tune_ks)
    r = tuning.grid_search(batches, pp=args.pp,
                           memory_token_budget=args.tune_budget,
                           world_size=world, include_heterogeneous=True,
                           **kw)
    print(f"tune: world={world} budget={args.tune_budget} "
          f"candidates={len(r.ranked)}")
    print(f"{'rank':>4} {'dp':>3} {'pp':>3} {'cp':>3} {'C':>6} {'K':>3} "
          f"{'plan':>6} {'makespan':>12} {'mem_tokens':>10}")
    for i, c in enumerate(r.ranked):
        print(f"{i:>4} {c.dp:>3} {c.pp:>3} {c.cp:>3} {c.chunk_size:>6} "
              f"{c.k:>3} {'solve' if c.heterogeneous else 'fixed':>6} "
              f"{c.makespan:>12.0f} {c.memory_tokens:>10}")
    return r.ranked[0]


if __name__ == "__main__":
    main()
