"""End-to-end ChunkFlow fine-tuning driver (paper Fig. 3 workflow).

Each iteration: sample a long-tail batch -> Algorithm 1 chunk construction ->
Algorithm 2 state-aware scheduling (gradients accumulate across chunks &
groups) -> one optimizer step. Mathematically equivalent to full-sequence
training (tests/test_chunked_equivalence.py), with peak activation memory
bounded by K * ChunkSize tokens.

CPU-scale entry point (the multi-pod path is exercised by launch/dryrun.py):

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 20 --chunk-size 256 --k 1 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import get_arch
from repro.core import chunked_step, chunking
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF
from repro.models import api
from repro.optim import adamw
from repro.checkpoint.io import save_checkpoint


def make_chunk_batches(cfg, seqs, lengths, chunk_size):
    chunks = chunking.construct_chunks(lengths, chunk_size)
    groups, standalone = chunking.group_chunks(chunks)
    to_dev = lambda m: {k: jnp.asarray(v) for k, v in m.items()}
    gb = [[to_dev(chunking.materialize_chunk(c, seqs)) for c in g]
          for g in groups.values()]
    sb = [to_dev(chunking.materialize_chunk(c, seqs)) for c in standalone]
    return gb, sb, chunks


def train(cfg, tc: TrainConfig, *, batch_per_step: int = 8,
          max_len: int = 2048, log_every: int = 1, checkpoint_path=None,
          sampler=None):
    params = api.init_params(cfg, jax.random.PRNGKey(tc.seed),
                             max_seq=max_len + 8)
    opt_state = adamw.adamw_init(params)
    sampler = sampler or LongTailSampler(PAPER_EVAL_CDF, min_len=32,
                                         seed=tc.seed, max_len=max_len)

    @jax.jit
    def apply_update(params, grads, opt_state, lr):
        return adamw.adamw_update(params, grads, opt_state, lr=lr,
                                  weight_decay=tc.weight_decay,
                                  grad_clip=tc.grad_clip)

    history = []
    for step in range(tc.total_steps):
        t0 = time.time()
        seqs, lengths = sampler.sample_batch(batch_per_step, cfg.vocab_size)
        gb, sb, chunks = make_chunk_batches(cfg, seqs, lengths, tc.chunk_size)
        loss, grads, stats = chunked_step.run_batch(
            cfg, params, gb, sb, k=tc.k_chunks)
        lr = adamw.cosine_schedule(step, base_lr=tc.learning_rate,
                                   warmup_steps=tc.warmup_steps,
                                   total_steps=tc.total_steps)
        params, opt_state, gnorm = apply_update(params, grads, opt_state, lr)
        dt = time.time() - t0
        history.append({
            "step": step, "loss": float(loss), "gnorm": float(gnorm),
            "sec": dt, "n_chunks": len(chunks),
            "n_groups": len(gb), "recomputes": stats.recompute_calls,
            "peak_residuals": stats.max_live_residuals,
        })
        if step % log_every == 0:
            h = history[-1]
            print(f"step {step:4d} loss {h['loss']:.4f} gnorm {h['gnorm']:.3f}"
                  f" chunks {h['n_chunks']:3d} (groups {h['n_groups']})"
                  f" recompute {h['recomputes']} {dt:.2f}s")
    if checkpoint_path:
        save_checkpoint(checkpoint_path,
                        {"params": params, "opt": opt_state},
                        step=tc.total_steps)
        print(f"checkpoint -> {checkpoint_path}")
    return params, opt_state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--chunk-size", type=int, default=256)
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=1024)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(chunk_size=args.chunk_size, k_chunks=args.k,
                     learning_rate=args.lr, total_steps=args.steps)
    train(cfg, tc, batch_per_step=args.batch, max_len=args.max_len,
          checkpoint_path=args.checkpoint)


if __name__ == "__main__":
    main()
