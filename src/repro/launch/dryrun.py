import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, dump roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl

The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init) — that is why it sits before the module docstring's siblings.
"""
import argparse
import json
import re
import sys
import time

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHS, SKIPPED_PAIRS, get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as specs_lib

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[2,16,512]' -> bytes; '(f32[4], f32[8])' handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (SPMD-partitioned,
    per-device) HLO. Convention documented in EXPERIMENTS.md §Roofline."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # '%name = TYPE op-name(' — result shape sits between '=' and op name
        for c in _COLLECTIVES:
            marker = f" {c}("
            alt = f" {c}-start("
            if marker in line or alt in line:
                lhs = line.split(marker)[0] if marker in line \
                    else line.split(alt)[0]
                if "=" not in lhs:
                    continue
                out[c] += _shape_bytes(lhs.split("=", 1)[1])
                counts[c] += 1
                break
    out["counts"] = counts
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    row = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind}
    t0 = time.time()
    args, shardings, step = specs_lib.input_specs(cfg, shape, mesh)
    with mesh:
        lowered = jax.jit(step, in_shardings=shardings).lower(*args)
        row["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        row["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    row["mem"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        None),
    }
    row["flops_xla_body"] = cost.get("flops") if cost else None
    row["bytes_xla_body"] = cost.get("bytes accessed") if cost else None
    # loop-aware analysis (while-loop trip-count multipliers) — the numbers
    # the roofline report actually uses
    from repro.launch.hlo_analysis import analyze
    a = analyze(compiled.as_text())
    row["flops"] = a["flops"]
    row["hbm_bytes"] = a["hbm_bytes"]
    row["collectives"] = a["collective_bytes"]
    row["collective_total"] = a["collective_total"]
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {row['mesh']}: "
              f"lower {row['lower_s']}s compile {row['compile_s']}s")
        print(f"  memory_analysis: {row['mem']}")
        print(f"  per-device: flops={row['flops']:.3e} "
              f"hbm_bytes={row['hbm_bytes']:.3e} "
              f"collective={row['collective_total']/1e9:.2f}GB")
        coll = {k: f"{v/1e9:.2f}GB" for k, v in row["collectives"].items() if v}
        print(f"  collectives: {coll or 'none'}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every runnable (arch x shape) pair")
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    if args.all:
        pairs = [(a, s) for a in ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    failures = []
    for arch, shape in pairs:
        if (arch, shape) in SKIPPED_PAIRS:
            reason = SKIPPED_PAIRS[(arch, shape)]
            print(f"[dryrun] SKIP {arch} x {shape}: {reason}")
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps({"arch": arch, "shape": shape,
                                        "skipped": reason}) + "\n")
            continue
        for mp in meshes[args.mesh]:
            try:
                row = run_one(arch, shape, mp)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(row) + "\n")
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                print(f"[dryrun] FAIL {arch} x {shape} "
                      f"{'multi' if mp else 'single'}: {e!r}")
                failures.append((arch, shape, mp, repr(e)))
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(
                            {"arch": arch, "shape": shape,
                             "mesh": "2x16x16" if mp else "16x16",
                             "error": repr(e)[:500]}) + "\n")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES")
        sys.exit(1)
    print("[dryrun] all combinations lowered and compiled OK")


if __name__ == "__main__":
    main()
