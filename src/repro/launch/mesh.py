"""Production mesh builders (functions, never module-level constants — so
importing this module never touches jax device state).

This module is also the single source of truth for mesh-axis NAMES:
``MESH_AXES`` below is the canonical registry that every ``shard_map`` /
``ppermute`` / ``psum`` / ``PartitionSpec`` axis string in the repo must come
from. The chunklint static analyzer (``python -m repro.analysis``) parses the
registry straight out of this file's AST and flags any axis literal outside
it, so a typo'd axis name fails CI instead of silently becoming replication.
Add a new axis HERE first, then use it at call sites.
"""
from __future__ import annotations

import jax

# Canonical mesh-axis registry (chunklint check CF-AX*). Order is major ->
# minor as the builders below lay them out:
#   "pod"   multi-pod data parallelism (production inference mesh)
#   "data"  data parallelism — wave rows of the chunk planner
#   "pipe"  pipeline stages — Algorithm 2's rotation ring
#   "model" tensor/expert parallelism (Megatron TP rules in sharding.py)
#   "seq"   context parallelism — the K/V ppermute ring, always minor
MESH_AXES = ("pod", "data", "pipe", "model", "seq")


def _check_axes(axes):
    unknown = [a for a in axes if a not in MESH_AXES]
    if unknown:
        raise ValueError(
            f"unknown mesh axis name(s) {unknown!r}: the canonical registry "
            f"is MESH_AXES={MESH_AXES!r} (launch/mesh.py) — register new "
            "axes there before building meshes with them")
    return axes


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model). Multi-pod: 2 x 256."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, _check_axes(axes))


def make_data_mesh(n_data: int = None):
    """Pure data-parallel mesh for the chunk-group orchestrator
    (core/chunked_step.run_batch with mesh=...). Defaults to every visible
    device; on CPU use XLA_FLAGS=--xla_force_host_platform_device_count=N."""
    n = n_data or len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_pipeline_mesh(n_stages: int = 4, data: int = 1):
    """Small mesh for the shard_map pipeline executor (tests / examples)."""
    if data > 1:
        return jax.make_mesh((n_stages, data), ("pipe", "data"))
    return jax.make_mesh((n_stages,), ("pipe",))


def make_train_mesh(data: int = 1, pipe: int = 1, seq: int = 1):
    """Up-to-3D (data x pipe x seq) mesh for the ChunkFlow trainers
    (train.py --dp/--pp/--cp). Needs data*pipe*seq visible devices; on CPU
    force them with XLA_FLAGS=--xla_force_host_platform_device_count=N.

    "seq" is the context-parallel axis: a chunk's tokens are sharded over it
    and its K/V circulates as a ppermute ring
    (distributed/context_parallel.py), so "seq" sits minor — ring neighbors
    land on adjacent devices. Degenerate axes are dropped: pipe == seq == 1
    gives the pure-DP mesh (axis still named "data")."""
    if seq > 1:
        if pipe > 1:
            return jax.make_mesh((data, pipe, seq), ("data", "pipe", "seq"))
        return jax.make_mesh((data, seq), ("data", "seq"))
    if pipe <= 1:
        return make_data_mesh(data)
    return jax.make_mesh((data, pipe), ("data", "pipe"))


def mesh_for_config(config):
    """Mesh for a tuner pick — a `tuning.LaunchConfig` (or anything with
    .dp/.pp/.cp) -> the matching train mesh, or None for the trivial
    1x1x1 config (single-device path, no mesh placement)."""
    dp, pp, cp = config.dp, config.pp, config.cp
    if dp * pp * cp <= 1:
        return None
    return make_train_mesh(dp, pp, cp)
