"""Serving driver.

Default path: the continuous-batching engine (`repro.serving`) — paged KV
cache, chunk-centric admission scheduler, one compiled step per tick.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 64 --gen 16

`--static` falls back to the static-batch reference below: chunked prefill
(ChunkFlow's chunk-by-chunk forward doubles as memory-bounded prefill) +
dense KV-cache decode. The engine is tested token-exact against this path
(tests/test_engine.py), so the reference doubles as the serving oracle.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models import api, decode
from repro.core import statestore as ss


# ---------------------------------------------------- static-batch oracle ---
def chunked_prefill(cfg, params, tokens, chunk_size: int):
    """Prefill a batch of prompts chunk-by-chunk (bounded activation memory,
    the serving counterpart of Algorithm 2 phase 1). Returns (last_logits,
    kv_state).

    Attention-family tail chunks are padded to ``chunk_size`` with seg=0
    (masked exactly, like `chunking.materialize_chunk` does for training):
    every chunk presents ONE jit signature, and MoE expert capacity —
    `moe.moe_capacity` is a function of the chunk length — stays uniform
    across chunks, matching what the serving engine's fixed-size chunk slots
    compute. The returned state is trimmed back to the ``T`` real slots.
    """
    B, T = tokens.shape
    attn = cfg.family in ("dense", "moe", "vlm")
    state = None
    last_logits = None
    for s0 in range(0, T, chunk_size):
        piece = tokens[:, s0: s0 + chunk_size]
        Tp = piece.shape[1]
        if attn and Tp < chunk_size:
            piece = jnp.concatenate(
                [piece, jnp.zeros((B, chunk_size - Tp), piece.dtype)], axis=1)
        Tc = piece.shape[1]
        seg = (jnp.arange(Tc) < Tp).astype(jnp.int32)[None].repeat(B, 0)
        batch = {
            "tokens": piece,
            "segment_ids": seg,
            "positions": (s0 + jnp.arange(Tc, dtype=jnp.int32))[None].repeat(B, 0),
        }
        if cfg.mrope:
            batch["positions"] = jnp.stack([batch["positions"]] * 3, -1)
        logits, state, _ = api.forward(cfg, params, batch, state)
        last_logits = logits[:, Tp - 1]
    if attn and state["k"].shape[2] > T:      # drop tail-chunk capacity pad
        state = {"k": state["k"][:, :, :T], "v": state["v"][:, :, :T],
                 "pos": state["pos"][:, :T], "seg": state["seg"][:, :T]}
    return last_logits, state


def state_to_cache(cfg, params, state, max_seq: int, batch: int):
    """Convert the prefill chunk-state into a fixed-size decode cache.

    Attention families carry a (L, B, S, Hkv, hd) K/V state that maps onto
    the dense decode cache. The ssm recurrent state has no sequence axis —
    it already *is* the decode cache (tests/test_serving.py), so it passes
    through unchanged. Hybrid / enc-dec states need family-specific plumbing
    (`decode.init_decode_cache` documents each layout); converting them here
    would silently drop conv tails / cross-KV.
    """
    if cfg.family in ("dense", "moe", "vlm"):
        cache = decode.init_decode_cache(cfg, batch, max_seq)
        P = state["k"].shape[2]
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], state["k"].astype(cache["k"].dtype), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], state["v"].astype(cache["v"].dtype), 0, axis=2)
        return cache, P
    if cfg.family == "ssm":
        return state, 0
    raise NotImplementedError(
        f"state_to_cache: config {cfg.name!r} requests family "
        f"{cfg.family!r}, but only {{'dense', 'moe', 'vlm', 'ssm'}} are "
        "supported — build the cache with decode.init_decode_cache and "
        "thread the family-specific state (hybrid per-block kind dispatch, "
        "audio cross-KV) explicitly")


def generate(cfg, params, prompts, *, gen_len: int, chunk_size: int = 256,
             greedy: bool = True, key=None):
    B, T = prompts.shape
    last_logits, state = chunked_prefill(cfg, params, prompts, chunk_size)
    max_seq = T + gen_len + 1
    cache, plen = state_to_cache(cfg, params, state, max_seq, B)

    step = jax.jit(lambda p, c, t, l: decode.decode_step(cfg, p, c, t, l))
    out = []
    tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    out.append(tok)
    pos = T
    for _ in range(gen_len - 1):
        logits, cache = step(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1)


# ------------------------------------------------------------ engine path ---
def serve_engine(cfg, params, prompts, *, gen_len: int, chunk_size: int,
                 page_size: int = None):
    """Run the batch through the continuous-batching engine. Returns
    (tokens (B, gen_len), engine) — a thin client of `repro.serving`."""
    from repro.serving import Engine, EngineConfig, trace_requests

    B, T = prompts.shape
    page_size = page_size or min(chunk_size, 16)
    chunk_size = ss.round_up(chunk_size, page_size)
    max_len = ss.round_up(T + gen_len, chunk_size)
    maxp = ss.pages_needed(max_len, page_size)
    ecfg = EngineConfig(
        page_size=page_size,
        pages_total=1 + B * maxp,
        max_running=B,
        prefill_chunk=chunk_size,
        prefill_slots=1,
        max_pages_per_req=maxp,
    )
    engine = Engine(cfg, params, ecfg)
    reqs = trace_requests([T] * B, vocab_size=cfg.vocab_size,
                          max_new_tokens=gen_len)
    for i, r in enumerate(reqs):
        r.prompt = np.asarray(prompts[i])
    results = engine.run(reqs)
    results.sort(key=lambda r: r.req_id)
    return jnp.asarray([r.tokens for r in results], jnp.int32), engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=32)
    ap.add_argument("--static", action="store_true",
                    help="static-batch reference path instead of the engine")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0),
                             max_seq=args.prompt_len + args.gen + 8)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 1,
                                 cfg.vocab_size)
    t0 = time.time()
    if args.static:
        toks = generate(cfg, params, prompts, gen_len=args.gen,
                        chunk_size=args.chunk_size)
    else:
        toks, engine = serve_engine(cfg, params, prompts, gen_len=args.gen,
                                    chunk_size=args.chunk_size)
        print(engine.summary())
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(toks[:, :12]))


if __name__ == "__main__":
    main()
