"""Batched serving driver: chunked prefill (ChunkFlow's chunk-by-chunk
forward doubles as memory-bounded prefill) + KV-cache decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.models import api, decode
from repro.core import statestore as ss


def chunked_prefill(cfg, params, tokens, chunk_size: int):
    """Prefill a batch of prompts chunk-by-chunk (bounded activation memory,
    the serving counterpart of Algorithm 2 phase 1). Returns (last_logits,
    kv_state)."""
    B, T = tokens.shape
    state = None
    logits = None
    for s0 in range(0, T, chunk_size):
        piece = tokens[:, s0: s0 + chunk_size]
        Tp = piece.shape[1]
        batch = {
            "tokens": piece,
            "segment_ids": jnp.ones((B, Tp), jnp.int32),
            "positions": (s0 + jnp.arange(Tp, dtype=jnp.int32))[None].repeat(B, 0),
        }
        if cfg.mrope:
            batch["positions"] = jnp.stack([batch["positions"]] * 3, -1)
        logits, state, _ = api.forward(cfg, params, batch, state)
    return logits[:, -1], state


def state_to_cache(cfg, params, state, max_seq: int, batch: int):
    """Convert the prefill chunk-state into a fixed-size decode cache."""
    cache = decode.init_decode_cache(cfg, batch, max_seq)
    if cfg.family in ("dense", "moe", "vlm"):
        P = state["k"].shape[2]
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], state["k"].astype(cache["k"].dtype), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], state["v"].astype(cache["v"].dtype), 0, axis=2)
        return cache, P
    if cfg.family == "ssm":
        return state, 0
    raise NotImplementedError(cfg.family)


def generate(cfg, params, prompts, *, gen_len: int, chunk_size: int = 256,
             greedy: bool = True, key=None):
    B, T = prompts.shape
    last_logits, state = chunked_prefill(cfg, params, prompts, chunk_size)
    max_seq = T + gen_len + 1
    cache, plen = state_to_cache(cfg, params, state, max_seq, B)

    step = jax.jit(lambda p, c, t, l: decode.decode_step(cfg, p, c, t, l))
    out = []
    tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    out.append(tok)
    pos = T
    for i in range(gen_len - 1):
        logits, cache = step(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0),
                             max_seq=args.prompt_len + args.gen + 8)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 1,
                                 cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompts, gen_len=args.gen,
                    chunk_size=args.chunk_size)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(toks[:, :12]))


if __name__ == "__main__":
    main()
