"""Single-token decode with KV / recurrent caches (serve_step substrate).

Cache layout (per family):
  dense/moe/vlm : {"k","v": (L, B, S, Hkv, hd)}            + scalar cache_len
  ssm           : {"ssm": (L,B,H,P,S), "conv": (L,B,W-1,CD)}
  hybrid        : {"attn": {k,v (nb,...)}, "mamba": {... (nb, nm, ...)}}
  audio         : {"k","v" self (L,...), "ck","cv" cross (L,B,Se,Hkv,hd)}

decode_step writes the new token's K/V at slot ``cache_len`` and attends to
slots ``<= cache_len``. Sliding-window layers (gemma2 local) mask by position
distance — the cache stays full-size in the baseline (see EXPERIMENTS.md §Perf
for the ring-buffer optimization).

Paged variant (the serving engine's cache, attention families only):
  {"k","v": (L, pages_total, page_size, Hkv, hd)}          + per-request
  (B,) cache_lens and (B, n_pages_per_req) page tables. `decode_step_paged`
  scatters each request's new K/V into page ``table[b, len // P]`` at offset
  ``len % P`` and attends through the table — per-request lengths come for
  free, and pool memory is fixed at ``pages_total * page_size`` slots no
  matter how long any one request runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2, moe as moe_lib
from repro.models.api import _layer_windows, _unembed, encode_audio, BIG_WINDOW


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None,
                      ring_local: bool = False):
    """ring_local: for local/global alternating archs (gemma2), allocate the
    local layers' cache as a sliding-window ring of ``cfg.sliding_window``
    slots instead of max_seq — the §Perf memory optimization for 500K decode
    (half the layers hold 4K slots instead of 512K)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim

    def kv(n_layers, seq):
        return {
            "k": jnp.zeros((n_layers, batch, seq, cfg.padded_num_kv_heads, hd), dtype),
            "v": jnp.zeros((n_layers, batch, seq, cfg.padded_num_kv_heads, hd), dtype),
        }

    if ring_local and cfg.local_global_alternate and cfg.sliding_window:
        assert cfg.family in ("dense", "moe", "vlm")
        assert cfg.num_layers % 2 == 0
        half = cfg.num_layers // 2
        g = kv(half, max_seq)                     # odd layers: global
        l = kv(half, cfg.sliding_window)          # even layers: local ring
        return {"k_global": g["k"], "v_global": g["v"],
                "k_local": l["k"], "v_local": l["v"],
                "ring_pos": jnp.full((cfg.sliding_window,), -1, jnp.int32)}

    def mamba_state(prefix):
        G = 1
        conv_dim = cfg.d_inner + 2 * G * cfg.ssm_state
        return {
            "ssm": jnp.zeros(prefix + (batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                       cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros(prefix + (batch, cfg.ssm_conv_width - 1, conv_dim),
                              jnp.float32),
        }

    if cfg.family in ("dense", "moe", "vlm"):
        return kv(cfg.num_layers, max_seq)
    if cfg.family == "ssm":
        return mamba_state((cfg.num_layers,))
    if cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.attn_every
        return {"attn": kv(nb, max_seq),
                "mamba": mamba_state((nb, cfg.attn_every - 1))}
    if cfg.family == "audio":
        c = kv(cfg.num_layers, max_seq)
        c["ck"] = jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                             cfg.padded_num_kv_heads, hd), dtype)
        c["cv"] = jnp.zeros_like(c["ck"])
        return c
    raise ValueError(cfg.family)


def init_paged_cache(cfg: ModelConfig, pages_total: int, page_size: int,
                     dtype=None):
    """Paged KV pool for `decode_step_paged`: (L, pages_total, page_size,
    Hkv, hd) per K/V. Page 0 is the *null page* by convention — the allocator
    (serving/kv_pages.py) never hands it out, padded page-table entries and
    inactive request slots point at it."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"paged KV cache supports attention families (dense/moe/vlm); "
            f"got {cfg.family!r} — use decode.init_decode_cache")
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, pages_total, page_size,
             cfg.padded_num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _decode_attn_paged(p, x, cfg, k_pages, v_pages, cache_lens, page_tables,
                       window, attn_softcap):
    """x: (B,1,D); k/v_pages: (n_pages, page_size, Hkv, hd); cache_lens (B,);
    page_tables (B, n_pages_per_req). Per-request cache lengths — request b
    writes at slot ``cache_lens[b]`` and attends slots ``<= cache_lens[b]``
    of its own pages. Returns (out (B,1,D), k_pages, v_pages)."""
    from repro.core.statestore import page_slot
    from repro.kernels.decode_attention import paged_decode_attention

    B = x.shape[0]
    hd = cfg.resolved_head_dim
    page_size = k_pages.shape[1]
    n_pages_per_req = page_tables.shape[1]

    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, cfg.padded_num_heads, hd)
    k = k.reshape(B, 1, cfg.padded_num_kv_heads, hd)
    v = v.reshape(B, 1, cfg.padded_num_kv_heads, hd)

    pos = cache_lens[:, None]                       # (B, 1) per-request
    if cfg.rope_theta:
        if cfg.mrope:
            pos3 = jnp.broadcast_to(pos[..., None], (B, 1, 3))
            q = L.apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k = L.apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)

    # scatter the new token's K/V into its page (requests own disjoint pages,
    # inactive slots are routed to the null page 0 by the scheduler)
    tbl_idx, offset = page_slot(cache_lens, page_size)
    pages = jnp.take_along_axis(page_tables, tbl_idx[:, None], axis=1)[:, 0]
    k_pages = k_pages.at[pages, offset].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[pages, offset].set(v[:, 0].astype(v_pages.dtype))

    if cfg.attn_backend in ("pallas", "pallas_interpret"):
        out = paged_decode_attention(
            q.transpose(0, 2, 1, 3), k_pages, v_pages, page_tables,
            cache_lens, window=window, softcap=attn_softcap,
            interpret=cfg.attn_backend == "pallas_interpret")
        out = out.transpose(0, 2, 1, 3)             # (B, 1, Hq, hd)
    else:
        S = n_pages_per_req * page_size
        keys = k_pages[page_tables].reshape(B, S, k_pages.shape[2], hd)
        vals = v_pages[page_tables].reshape(B, S, v_pages.shape[2], hd)
        slot = jnp.arange(S, dtype=jnp.int32)
        valid = slot[None] <= cache_lens[:, None]
        valid &= (cache_lens[:, None] - slot[None]) < window
        mask = valid[:, None, :]                    # (B, 1, S)
        out = L.sdpa(q, keys, vals, mask, attn_softcap=attn_softcap)
    out = out.reshape(B, 1, cfg.padded_num_heads * hd) @ p["wo"]
    return out, k_pages, v_pages


def decode_step_paged(cfg: ModelConfig, params, cache, tokens, cache_lens,
                      page_tables):
    """One decode step through the paged KV pool.

    tokens: (B,1) int32; cache_lens: (B,) int32 per-request write slots;
    page_tables: (B, n_pages_per_req) int32. -> (logits (B,1,V), new_cache).
    Unlike `decode_step`, batch rows advance independently — this is the
    continuous-batching substrate (serving/engine.py).
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"decode_step_paged supports attention families (dense/moe/vlm); "
            f"got {cfg.family!r} — use decode.decode_step")
    x = params["embed"][tokens]
    windows = jnp.asarray(_layer_windows(cfg))

    def layer_fn(x, xs):
        lp, window, kp, vp = xs
        h, kp, vp = _decode_attn_paged(
            lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, kp, vp,
            cache_lens, page_tables, window, cfg.attn_softcap)
        x = x + h
        xn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.num_experts:
            h2, _ = moe_lib.moe_layer(lp["moe"], xn, cfg)
        else:
            h2 = L.swiglu_mlp(lp["mlp"], xn)
        return x + h2, (kp, vp)

    x, (nk, nv) = jax.lax.scan(
        layer_fn, x, (params["layers"], windows, cache["k"], cache["v"]))
    logits = _unembed(cfg, params, L.rms_norm(x, params["ln_f"], cfg.norm_eps))
    return logits, {"k": nk, "v": nv}


def _decode_attn(p, x, cfg, cache_k, cache_v, cache_len, window,
                 attn_softcap):
    """x: (B,1,D). Returns (out (B,1,D), new_cache_k, new_cache_v)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    S = cache_k.shape[1]      # (B, S, Hkv, hd)

    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, cfg.padded_num_heads, hd)
    k = k.reshape(B, 1, cfg.padded_num_kv_heads, hd)
    v = v.reshape(B, 1, cfg.padded_num_kv_heads, hd)

    pos = jnp.full((B, 1), cache_len, jnp.int32)
    if cfg.rope_theta:
        if cfg.mrope:
            pos3 = jnp.broadcast_to(pos[..., None], (B, 1, 3))
            q = L.apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k = L.apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)

    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                                  cache_len, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                                  cache_len, axis=1)

    slots = jnp.arange(S, dtype=jnp.int32)
    valid = slots <= cache_len
    if window is not None:
        valid &= (cache_len - slots) < window
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, S))
    out = L.sdpa(q, cache_k, cache_v, mask, attn_softcap=attn_softcap)
    out = out.reshape(B, 1, cfg.padded_num_heads * hd) @ p["wo"]
    return out, cache_k, cache_v


def _decode_attn_ring(p, x, cfg, ck, cv, ring_pos, cache_len, attn_softcap):
    """Sliding-window decode against a ring cache. ck/cv: (B, W, Hkv, hd);
    ring_pos: (W,) absolute position held in each slot (-1 = empty)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    W = ck.shape[1]
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, cfg.padded_num_heads, hd)
    k = k.reshape(B, 1, cfg.padded_num_kv_heads, hd)
    v = v.reshape(B, 1, cfg.padded_num_kv_heads, hd)
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    if cfg.rope_theta:
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    slot = cache_len % W
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, 1)
    new_ring = ring_pos.at[slot].set(cache_len)
    valid = (new_ring >= 0) & (new_ring <= cache_len) \
        & ((cache_len - new_ring) < cfg.sliding_window)
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, W))
    out = L.sdpa(q, ck, cv, mask, attn_softcap=attn_softcap)
    out = out.reshape(B, 1, cfg.padded_num_heads * hd) @ p["wo"]
    return out, ck, cv, new_ring


def _decode_ring_pairs(cfg, params, cache, tokens, cache_len):
    """Local/global alternating decode with ring local caches (gemma2)."""
    x = params["embed"][tokens]
    stacked = params["layers"]
    half = cfg.num_layers // 2
    pair = lambda a: a.reshape(half, 2, *a.shape[1:])
    pairs = jax.tree.map(pair, stacked)
    ring0 = cache["ring_pos"]

    def pair_fn(carry, xs):
        x, ring = carry
        pp, lk, lv, gk, gv = xs
        loc = jax.tree.map(lambda a: a[0], pp)
        glo = jax.tree.map(lambda a: a[1], pp)
        h, lk, lv, ring = _decode_attn_ring(
            loc["attn"], L.rms_norm(x, loc["ln1"], cfg.norm_eps), cfg,
            lk, lv, ring, cache_len, cfg.attn_softcap)
        x = x + h
        x = x + L.swiglu_mlp(loc["mlp"],
                             L.rms_norm(x, loc["ln2"], cfg.norm_eps))
        h, gk, gv = _decode_attn(glo["attn"],
                                 L.rms_norm(x, glo["ln1"], cfg.norm_eps),
                                 cfg, gk, gv, cache_len, None,
                                 cfg.attn_softcap)
        x = x + h
        x = x + L.swiglu_mlp(glo["mlp"],
                             L.rms_norm(x, glo["ln2"], cfg.norm_eps))
        return (x, ring), (lk, lv, gk, gv)

    (x, ring), (lk, lv, gk, gv) = jax.lax.scan(
        pair_fn, (x, ring0),
        (pairs, cache["k_local"], cache["v_local"], cache["k_global"],
         cache["v_global"]))
    logits = _unembed(cfg, params, L.rms_norm(x, params["ln_f"], cfg.norm_eps))
    return logits, {"k_local": lk, "v_local": lv, "k_global": gk,
                    "v_global": gv, "ring_pos": ring}


def decode_step(cfg: ModelConfig, params, cache, tokens, cache_len,
                positions=None):
    """tokens: (B, 1) -> (logits (B,1,V), new_cache). cache_len: scalar int."""
    B = tokens.shape[0]

    if isinstance(cache, dict) and "k_local" in cache:
        return _decode_ring_pairs(cfg, params, cache, tokens, cache_len)

    if cfg.family in ("dense", "moe", "vlm"):
        x = params["embed"][tokens]
        windows = jnp.asarray(_layer_windows(cfg))

        def layer_fn(x, xs):
            lp, window, ck, cv = xs
            h, ck, cv = _decode_attn(lp["attn"],
                                     L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                                     cfg, ck, cv, cache_len, window,
                                     cfg.attn_softcap)
            x = x + h
            xn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.num_experts:
                h2, _ = moe_lib.moe_layer(lp["moe"], xn, cfg)
            else:
                h2 = L.swiglu_mlp(lp["mlp"], xn)
            return x + h2, (ck, cv)

        x, (nk, nv) = jax.lax.scan(
            layer_fn, x, (params["layers"], windows, cache["k"], cache["v"]))
        logits = _unembed(cfg, params,
                          L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        return logits, {"k": nk, "v": nv}

    if cfg.family == "ssm":
        x = params["embed"][tokens]

        def layer_fn(x, xs):
            lp, st = xs
            h, new_st = mamba2.mamba_decode_step(
                lp["mamba"], L.rms_norm(x, lp["ln"], cfg.norm_eps), cfg, st)
            return x + h, new_st

        x, new_state = jax.lax.scan(layer_fn, x, (params["layers"], cache))
        logits = _unembed(cfg, params,
                          L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        return logits, new_state

    if cfg.family == "hybrid":
        x = params["embed"][tokens]

        def block_fn(x, xs):
            bp, m_st, ck, cv = xs

            def sub_fn(x, sub):
                mp, st = sub
                h, new_st = mamba2.mamba_decode_step(
                    mp["mamba"], L.rms_norm(x, mp["ln"], cfg.norm_eps), cfg, st)
                return x + h, new_st

            x, new_m = jax.lax.scan(sub_fn, x, (bp["mamba"], m_st))
            # (hybrid blocks keep the MoE MLP after each mixer)
            def moe_res(x, op):
                h, _ = moe_lib.moe_layer(
                    op["moe"], L.rms_norm(x, op["ln"], cfg.norm_eps), cfg)
                return x + h

            x, _ = jax.lax.scan(lambda xx, op: (moe_res(xx, op), None),
                                x, bp["moe_m"])
            h, ck, cv = _decode_attn(
                bp["attn"]["attn"],
                L.rms_norm(x, bp["attn"]["ln"], cfg.norm_eps), cfg, ck, cv,
                cache_len, None, cfg.attn_softcap)
            x = x + h
            x = moe_res(x, bp["moe_a"])
            return x, (new_m, ck, cv)

        x, (new_m, nk, nv) = jax.lax.scan(
            block_fn, x,
            (params["blocks"], cache["mamba"], cache["attn"]["k"],
             cache["attn"]["v"]))
        logits = _unembed(cfg, params,
                          L.rms_norm(x, params["ln_f"], cfg.norm_eps))
        return logits, {"attn": {"k": nk, "v": nv}, "mamba": new_m}

    if cfg.family == "audio":
        x = params["embed"][tokens] + params["dec_pos"][
            jnp.full((B, 1), cache_len, jnp.int32)]
        hd = cfg.resolved_head_dim
        Se = cfg.encoder_seq

        def layer_fn(x, xs):
            lp, ck, cv, xk, xv = xs
            h, ck, cv = _decode_attn(
                lp["self_attn"], L.layer_norm(x, lp["ln1_w"], lp["ln1_b"]),
                cfg, ck, cv, cache_len, None, 0.0)
            x = x + h
            xn = L.layer_norm(x, lp["ln2_w"], lp["ln2_b"])
            q = (xn @ lp["cross_attn"]["wq"]).reshape(B, 1, cfg.padded_num_heads, hd)
            mask = jnp.ones((B, 1, Se), bool)
            h = L.sdpa(q, xk, xv, mask)
            h = h.reshape(B, 1, cfg.padded_num_heads * hd) @ lp["cross_attn"]["wo"]
            x = x + h
            xn = L.layer_norm(x, lp["ln3_w"], lp["ln3_b"])
            return x + L.gelu_mlp(lp["mlp"], xn), (ck, cv)

        x, (nk, nv) = jax.lax.scan(
            layer_fn, x,
            (params["dec_layers"], cache["k"], cache["v"], cache["ck"],
             cache["cv"]))
        x = L.layer_norm(x, params["dec_ln_f_w"], params["dec_ln_f_b"])
        logits = (x @ params["embed"].T).astype(jnp.float32)
        from repro.models.api import padded_vocab
        vp = padded_vocab(cfg)
        if vp != cfg.vocab_size:
            logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, -1e30)
        return logits, {"k": nk, "v": nv, "ck": cache["ck"], "cv": cache["cv"]}

    raise ValueError(cfg.family)


def prefill_audio_cross(cfg: ModelConfig, params, cache, encoder_embeds):
    """Populate whisper cross K/V from the encoder output."""
    enc_out = encode_audio(cfg, params, encoder_embeds)
    B, Se, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def layer_fn(_, lp):
        ck = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, Se, cfg.padded_num_kv_heads, hd)
        cv = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, Se, cfg.padded_num_kv_heads, hd)
        return None, (ck, cv)

    _, (ck, cv) = jax.lax.scan(layer_fn, None, params["dec_layers"])
    return dict(cache, ck=ck.astype(cache["ck"].dtype),
                cv=cv.astype(cache["cv"].dtype))
