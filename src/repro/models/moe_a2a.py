"""Expert-parallel MoE dispatch via shard_map (§Perf kimi iteration).

The pjit/GSPMD scatter dispatch in moe.py round-trips token buffers through
all-gathers over the TP axis and an all-reduce combine — measured 4.6 TB/step
on kimi-1t train. But between transformer blocks the activations are already
*replicated* across the TP axis (Megatron layout), so no token movement is
needed at all: each shard locally selects the (token, k) assignments routed
to ITS E/msz experts, computes them, and one all-reduce (the irreducible
combine, which GSPMD also paid) merges the partial outputs.

Net: the dispatch all-gathers disappear; traffic drops to exactly one
(B, T, D) all-reduce per MoE layer.

Numerics match moe.moe_layer under the same per-expert capacity policy;
tests/test_moe_a2a.py checks against the dense reference on a real mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.compat import shard_map
from repro.models.moe import moe_capacity


def _rank_in_group(group_ids, n_groups):
    oh = jax.nn.one_hot(group_ids, n_groups, dtype=jnp.int32)
    return (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1


def moe_layer_eplocal(p, x, cfg: ModelConfig, mesh, dp, axis: str = "model"):
    """x: (B, T, D) -> (out, aux). Requires cfg.num_experts % msz == 0 and
    TP-replicated activations (the Megatron layout this repo uses)."""
    msz = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    E, K, D = cfg.num_experts, cfg.experts_per_token, cfg.d_model
    assert E % msz == 0, (E, msz)
    E_loc = E // msz
    B, T, _ = x.shape
    C = moe_capacity(cfg, T)

    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    def body(wg, wu, wd, x_loc, idx_loc, gates_loc):
        r = jax.lax.axis_index(axis)
        b_loc, t, _ = x_loc.shape
        n = b_loc * t * K
        flat_e = idx_loc.reshape(n)
        mine = (flat_e // E_loc) == r
        eid = jnp.where(mine, flat_e % E_loc, E_loc)          # overflow bucket
        # per-(row-local-)expert capacity ranking, matching moe.moe_layer's
        # per-row capacity C (ranking is per batch row)
        eid_rows = eid.reshape(b_loc, t * K)
        pos = jax.vmap(lambda e: _rank_in_group(e, E_loc + 1))(eid_rows)
        keep = (pos < C) & (eid_rows < E_loc)
        pc = jnp.minimum(pos, C - 1)
        e2 = jnp.minimum(eid_rows, E_loc - 1)

        xrep = jnp.repeat(x_loc, K, axis=1)                   # (b, t*K, D)
        buf = jnp.zeros((b_loc, E_loc, C, D), x_loc.dtype)
        buf = jax.vmap(lambda b, e, c, v: b.at[e, c].add(v))(
            buf, e2, pc, xrep * keep[..., None].astype(x_loc.dtype))

        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg))
        h = h * jnp.einsum("becd,edf->becf", buf, wu)
        y = jnp.einsum("becf,efd->becd", h, wd)               # (b,E_loc,C,D)

        picked = jax.vmap(lambda o, e, c: o[e, c])(y, e2, pc)
        picked = picked * (gates_loc.reshape(b_loc, t * K, 1)
                           .astype(picked.dtype) * keep[..., None])
        out = picked.reshape(b_loc, t, K, D).sum(axis=2)
        return jax.lax.psum(out, axis)                        # the combine

    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None, None),
                  P(axis, None, None), P(dp, None, None),
                  P(dp, None, None), P(dp, None, None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(p["w_gate"], p["w_up"], p["w_down"], x, idx.astype(jnp.int32), gates)
    return out, aux
