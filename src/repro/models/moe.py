"""Top-k MoE with sort-free scatter dispatch.

TPU adaptation: instead of the GShard dispatch einsum (whose (B,T,E,C) tensors
explode for E=384) we rank tokens within each expert via a one-hot cumsum and
scatter them into per-row (E, C, D) buffers. Expert matmuls are plain einsums
whose HLO FLOP count equals the *active-parameter* cost (top-k × FFN), keeping
the roofline analysis honest. The expert dimension shards over the "model"
mesh axis (expert parallelism); GSPMD inserts the token all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.layers import dense_init


def init_moe(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype=dtype),
    }


def moe_capacity(cfg: ModelConfig, tokens_per_row: int) -> int:
    c = int(tokens_per_row * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts) + 1
    return max(c, cfg.experts_per_token)


def moe_layer(p, x, cfg: ModelConfig):
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar).

    Dispatch is per batch row (per-row capacity) so the ranking cumsum never
    crosses the data-parallel sharding boundary. Under an active mesh context
    (launch/specs.py) with a divisible expert count, dispatch switches to the
    shard_map EP-local path (moe_a2a.py) — measured 2.4 TB/step less dispatch
    traffic on kimi-1t (§Perf).
    """
    ctx = L._CTX
    if (ctx.get("mesh") is not None and ctx["msize"]
            and cfg.num_experts % ctx["msize"] == 0):
        from repro.models.moe_a2a import moe_layer_eplocal
        return moe_layer_eplocal(p, x, cfg, ctx["mesh"], ctx["dp"])
    B, T, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = moe_capacity(cfg, T)

    logits = x.astype(jnp.float32) @ p["router"]            # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                    # (B, T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * sum(frac_tokens * frac_probs).
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    one_hot_top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # --- rank each (token, k) pick within its expert ------------------------
    flat_idx = idx.reshape(B, T * K)                        # (B, N)
    oh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)       # (B, N, E)
    pos_in_e = (jnp.cumsum(oh, axis=1) * oh).sum(-1) - 1    # (B, N)
    keep = pos_in_e < C                                     # capacity drop
    pos_clip = jnp.minimum(pos_in_e, C - 1)

    # --- scatter tokens into (B, E, C, D) buffers (E shards over "model") ---
    x_rep = jnp.repeat(x, K, axis=1) * keep[..., None].astype(x.dtype)
    buf = L.constrain_moe(jnp.zeros((B, E, C, D), x.dtype))
    buf = jax.vmap(lambda b, e, c, v: b.at[e, c].add(v))(
        buf, flat_idx, pos_clip, x_rep)
    expert_in = L.constrain_moe(buf)

    # --- expert FFN (SwiGLU) -------------------------------------------------
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", expert_in, p["w_up"])
    out_e = jnp.einsum("becf,efd->becd", h, p["w_down"])    # (B, E, C, D)
    out_e = L.constrain_moe(out_e)

    # --- gather back & combine ----------------------------------------------
    picked = jax.vmap(lambda o, e, c: o[e, c])(out_e, flat_idx, pos_clip)
    picked = picked * (gates.reshape(B, T * K, 1).astype(picked.dtype)
                       * keep[..., None])
    out = picked.reshape(B, T, K, D).sum(axis=2)
    return out, aux
