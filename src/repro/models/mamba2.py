"""Mamba-2 / SSD (state-space duality) block in pure JAX [arXiv:2405.21060].

The SSD chunked algorithm is a natural fit for ChunkFlow: the inter-chunk
recurrent state (B_heads, head_dim, d_state) *is* the chunk state the paper's
StateStore carries — O(1) in sequence length, so the memory claim is even
stronger than for attention (DESIGN.md §4).

Layout: x (B, T, D) -> in_proj -> [z, xc, B, C, dt]; depthwise causal conv on
(xc|B|C); SSD scan over heads; gated RMSNorm; out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm


def init_mamba(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    DI = cfg.d_inner
    H = cfg.ssm_heads
    S = cfg.ssm_state
    G = 1  # single B/C group
    conv_dim = DI + 2 * G * S
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * DI + 2 * G * S + H), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), scale=0.1,
                             dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),              # A = -exp(A_log)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),                   # skip connection
        "norm_w": jnp.zeros((DI,), dtype),
        "out_proj": dense_init(ks[2], (DI, D), dtype=dtype),
    }


def _ssd_chunk_scan(xh, dt, A, Bm, Cm, chunk: int, init_state=None,
                    segments=None):
    """Chunked SSD scan (Mamba-2 Alg. 1 'SSD-minimal').

    xh: (B, T, H, P) values; dt: (B, T, H) softplus'd step; A: (H,) negative;
    Bm/Cm: (B, T, S) input/output projections (single group broadcast to H).
    segments: optional (B, T) int32 packed-segment ids — the recurrent state
    resets at segment boundaries (packed rows must be *contiguous* runs).
    Returns (y (B,T,H,P), final_state (B,H,P,S)).
    """
    Bsz, T, H, P = xh.shape
    S = Bm.shape[-1]
    nc = T // chunk
    xc = xh.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, S)
    Cc = Cm.reshape(Bsz, nc, chunk, S)

    from repro.models.layers import constrain_dim
    xc = constrain_dim(xc, 3, H)
    dtc = constrain_dim(dtc, 3, H)

    dA = dtc * A                                            # (B,nc,l,H)
    dA_cum = constrain_dim(jnp.cumsum(dA, axis=2), 3, H)    # within-chunk cumsum

    if segments is not None:
        segc = segments.reshape(Bsz, nc, chunk)
        same_ij = segc[:, :, :, None] == segc[:, :, None, :]        # (B,nc,i,j)
        to_last = (segc == segc[:, :, -1:])                         # (B,nc,l)
        prev_last = jnp.concatenate([segc[:, :1, 0], segc[:, :-1, -1]],
                                    axis=1)                         # (B,nc)
        from_prev = (segc == prev_last[:, :, None])                 # (B,nc,l)
        carry_ok = (segc[:, :, -1] == prev_last)                    # (B,nc)
    else:
        same_ij = to_last = from_prev = carry_ok = None

    # --- intra-chunk (quadratic within the chunk, causal) -------------------
    # L[b,c,h,i,j] = exp(dA_cum[i] - dA_cum[j]) for i >= j
    # NOTE: einsums are kept strictly pairwise with explicit elementwise
    # pre-multiplies — 4-operand einsums decompose into huge broadcast
    # intermediates ((B,nc,l,H,P,S)-sized) under XLA.
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # (B,nc,i,j,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    L = constrain_dim(L, 4, H)
    if same_ij is not None:
        L = L * same_ij[..., None]
    CB = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)              # (B,nc,i,j)
    Lw = constrain_dim(CB[..., None] * L, 4, H)             # (B,nc,i,j,H)
    xw = constrain_dim(dtc[..., None] * xc, 3, H)           # (B,nc,j,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", Lw, xw)
    y_intra = constrain_dim(y_intra, 3, H)

    # --- chunk states --------------------------------------------------------
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (B,nc,l,H)
    if to_last is not None:
        decay_to_end = decay_to_end * to_last[..., None]
    xw_states = constrain_dim((decay_to_end * dtc)[..., None] * xc, 3, H)
    states = jnp.einsum("bcls,bclhp->bchps", Bc, xw_states)  # (B,nc,H,P,S)
    states = constrain_dim(states, 2, H)

    # --- inter-chunk recurrence over chunk states ----------------------------
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])              # (B,nc,H)
    if carry_ok is not None:
        chunk_decay = chunk_decay * carry_ok[..., None]

    def step(carry, inp):
        s_prev = carry
        st, dec = inp
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    s0 = (jnp.zeros((Bsz, H, P, S), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,P,S)

    # --- inter-chunk output contribution -------------------------------------
    state_decay = jnp.exp(dA_cum)                           # decay from chunk start
    if from_prev is not None:
        state_decay = state_decay * from_prev[..., None]
    y_inter = jnp.einsum("bcls,bchps->bclhp", Cc, prev_states)
    y_inter = constrain_dim(y_inter, 3, H) * state_decay[..., None]
    y = constrain_dim((y_intra + y_inter), 3, H).reshape(Bsz, T, H, P)
    return y, final


def mamba_layer(p, x, cfg: ModelConfig, *, state=None, segment_ids=None):
    """x: (B, T, D) -> (out, new_state {"ssm": (B,H,P,S), "conv": (B,W-1,CD)}).

    ``state`` is the ChunkFlow chunk state: SSD state + conv tail of the
    previous chunk of the same sequence.
    """
    B, T, D = x.shape
    DI, H, S = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    P = cfg.ssm_head_dim
    W = cfg.ssm_conv_width
    G = 1

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :DI]
    xbc = zxbcdt[..., DI: 2 * DI + 2 * G * S]
    dt_raw = zxbcdt[..., 2 * DI + 2 * G * S:]

    # depthwise causal conv over (x|B|C) with carry-in tail
    if state is not None:
        tail = state["conv"].astype(xbc.dtype)
    else:
        tail = jnp.zeros((B, W - 1, xbc.shape[-1]), xbc.dtype)
    xbc_pad = jnp.concatenate([tail, xbc], axis=1)
    conv = sum(xbc_pad[:, i: i + T] * p["conv_w"][i] for i in range(W))
    xbc = jax.nn.silu(conv + p["conv_b"])
    new_conv_tail = xbc_pad[:, -(W - 1):]

    xc = xbc[..., :DI].reshape(B, T, H, P)
    Bm = xbc[..., DI: DI + S]
    Cm = xbc[..., DI + S:]

    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    chunk = min(cfg.ssm_chunk, T)
    # pad T to a multiple of chunk
    pad = (-T) % chunk
    seg = segment_ids
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        if seg is not None:
            seg = jnp.pad(seg, ((0, 0), (0, pad)))

    init = state["ssm"] if state is not None else None
    y, final = _ssd_chunk_scan(xc.astype(jnp.float32), dt, A,
                               Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                               chunk, init_state=init, segments=seg)
    y = y[:, :T]
    y = y + xc.astype(jnp.float32)[:, :T] * p["D"][None, None, :, None]
    y = y.reshape(B, T, DI).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": final, "conv": new_conv_tail}


def mamba_decode_step(p, x, cfg: ModelConfig, state):
    """Single-token recurrent update. x: (B, 1, D)."""
    B, _, D = x.shape
    DI, H, S, P, W = (cfg.d_inner, cfg.ssm_heads, cfg.ssm_state,
                      cfg.ssm_head_dim, cfg.ssm_conv_width)
    zxbcdt = x[:, 0] @ p["in_proj"]
    z = zxbcdt[..., :DI]
    xbc = zxbcdt[..., DI: 2 * DI + 2 * S]
    dt_raw = zxbcdt[..., 2 * DI + 2 * S:]

    conv_buf = jnp.concatenate([state["conv"].astype(xbc.dtype),
                                xbc[:, None, :]], axis=1)   # (B, W, CD)
    conv = sum(conv_buf[:, i] * p["conv_w"][i] for i in range(W))
    xbc = jax.nn.silu(conv + p["conv_b"])
    new_conv = conv_buf[:, 1:]

    xc = xbc[..., :DI].reshape(B, H, P)
    Bm = xbc[..., DI: DI + S]
    Cm = xbc[..., DI + S:]
    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, H)

    dA = jnp.exp(dt * A)                                    # (B, H)
    s = state["ssm"].astype(jnp.float32)
    s = s * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bs->bhps", dt, xc.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bhps,bs->bhp", s, Cm.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, DI).astype(x.dtype)
    y = rms_norm((y * jax.nn.silu(z))[:, None, :], p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": s, "conv": new_conv}


def init_mamba_state(cfg: ModelConfig, batch: int):
    G = 1
    conv_dim = cfg.d_inner + 2 * G * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.float32),
    }
