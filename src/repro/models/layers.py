"""Shared pure-JAX layer primitives.

Everything here is a pure function over param pytrees. Attention supports the
ChunkFlow contract: an optional *prefix KV state* (key/value tensors of earlier
chunks of the same sequence) is consumed and the layer returns its own K/V so
the scheduler can extend the state. Masks combine causality, packed-segment
ids, and optional sliding windows.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig

# --- activation-sharding hook -------------------------------------------
# When set (by launch/specs.py under pjit), the leading batch dim of
# attention intermediates is constrained to the DP mesh axes so GSPMD never
# trades batch sharding for partial head sharding inside scan bodies; MoE and
# SSD intermediates additionally pin their expert/head dim to the TP axis.
_CTX = {"dp": None, "model": "model", "msize": 0, "mesh": None}


@contextlib.contextmanager
def batch_sharding(dp_axes, model_size: int = 0, mesh=None):
    prev = dict(_CTX)
    _CTX.update(dp=tuple(dp_axes) if dp_axes else None, msize=model_size,
                mesh=mesh)
    try:
        yield
    finally:
        _CTX.update(prev)


_U = PartitionSpec.UNCONSTRAINED


def constrain_batch(x):
    """Pin the batch dim to DP; leave the rest to GSPMD (UNCONSTRAINED), so
    head/FFN sharding survives alongside."""
    if _CTX["dp"] is None:
        return x
    spec = PartitionSpec(_CTX["dp"], *([_U] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_moe(x):
    """(B, E, C, D) expert buffers: batch over DP, experts over TP (EP)."""
    if _CTX["dp"] is None:
        return x
    spec = PartitionSpec(_CTX["dp"], _CTX["model"], *([_U] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_dim(x, dim: int, dim_size: int):
    """Pin tensor dim to the TP axis (used for SSD head dims), batch to DP."""
    if _CTX["dp"] is None:
        return x
    spec = [_U] * x.ndim
    spec[0] = _CTX["dp"]
    if _CTX["msize"] and dim_size % _CTX["msize"] == 0:
        spec[dim] = _CTX["model"]
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def dense_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------- norms ----
def rms_norm(x, weight, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight + bias).astype(dt)


# ------------------------------------------------------------------ RoPE ----
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, T, H, D); positions: (B, T) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float):
    """Qwen2-VL M-RoPE. x: (B, T, H, D); positions3: (B, T, 3) — (t, h, w)
    components. Each rotary frequency slot is driven by one of the three
    position streams according to ``sections`` (sums to D/2)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d // 2)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                     # (B, T, 3)
        jnp.broadcast_to(sec_id, positions3.shape[:2] + (d // 2,)).astype(jnp.int32),
        axis=-1,
    )                                                       # (B, T, D/2)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention ----
NEG_INF = -1e30


def make_attention_mask(q_pos, k_pos, q_seg, k_seg, *, causal=True, window=None):
    """Bool mask (B, Tq, Tk): True = attend.

    q_pos/k_pos: (B, T) global positions; q_seg/k_seg: (B, T) segment ids
    (0 = padding, never attended/attending). ``window`` may be a traced scalar
    (per-layer local/global alternation) — use BIG_WINDOW-style sentinels for
    global layers rather than None when traced.
    """
    same_seg = (q_seg[:, :, None] == k_seg[:, None, :])
    valid = (q_seg[:, :, None] > 0) & (k_seg[:, None, :] > 0)
    mask = same_seg & valid
    if causal:
        mask &= q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    return mask


def sdpa(q, k, v, mask, *, attn_softcap: float = 0.0):
    """q: (B,Tq,Hq,D)  k,v: (B,Tk,Hkv,D)  mask: (B,Tq,Tk) -> (B,Tq,Hq,D)."""
    B, Tq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Tq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    if attn_softcap:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Tq, Hq, D).astype(q.dtype)


def blockwise_sdpa(q, k, v, mask_fn, *, q_block: int, kv_block: int,
                   attn_softcap: float = 0.0, kv_limits=None):
    """Flash-style online-softmax attention in pure JAX (q blocks outer,
    inner scan over kv blocks). Never materialises the (Tq, Tk) score matrix —
    this is the memory-safe path for 32K+ sequences on any backend.

    mask_fn(q_idx, k_idx) -> bool (B, q_block, kv_block); q_idx/k_idx are the
    *global token offsets* of the blocks.

    kv_limits: optional static per-q-block kv-block counts (causal triangle
    skipping — §Perf: halves attention FLOPs and KV HBM re-reads). When given,
    the q loop is unrolled so each inner scan has its own static length;
    otherwise a uniform (nq, nk) double scan is emitted.
    """
    B, Tq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nq, nk = Tq // q_block, Tk // kv_block
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    qr = q.reshape(B, nq, q_block, Hkv, G, D)
    kr = k.reshape(B, nk, kv_block, Hkv, D)
    vr = v.reshape(B, nk, kv_block, Hkv, D)

    def q_step(qi, limit):
        qb = qr[:, qi].astype(jnp.float32)                  # (B,qb,Hkv,G,D)

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = kr[:, ki].astype(jnp.float32)
            vb = vr[:, ki].astype(jnp.float32)
            s = constrain_batch(
                jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb) * scale)
            if attn_softcap:
                s = attn_softcap * jnp.tanh(s / attn_softcap)
            blk_mask = mask_fn(qi * q_block, ki * kv_block)  # (B,qb,kb)
            s = jnp.where(blk_mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = constrain_batch(jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32))
        l0 = constrain_batch(jnp.zeros((B, Hkv, G, q_block), jnp.float32))
        a0 = constrain_batch(jnp.zeros((B, Hkv, G, q_block, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(limit))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # (B,Hkv,G,qb,D)
        return out.transpose(0, 3, 1, 2, 4)                  # (B,qb,Hkv,G,D)

    if kv_limits is not None:
        outs = [q_step(qi, int(kv_limits[qi])) for qi in range(nq)]
        out = jnp.concatenate(outs, axis=1).reshape(B, Tq, Hq, D)
        return out.astype(q.dtype)

    _, outs = jax.lax.scan(lambda _, qi: (None, q_step(qi, nk)), None,
                           jnp.arange(nq))                   # (nq,B,qb,...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tq, Hq, D)
    return out.astype(q.dtype)


@dataclasses.dataclass
class AttnParams:
    """Just a naming convention — attention params are dicts:
    {wq, wk, wv, wo, (bq, bk, bv)}."""


def init_attention(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.padded_num_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.padded_num_kv_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.padded_num_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.padded_num_heads * hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.padded_num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.padded_num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.padded_num_kv_heads * hd,), dtype)
    return p


def attention_layer(p, x, cfg: ModelConfig, *, positions, segment_ids,
                    prefix=None, window=None, blockwise_threshold=8192,
                    cross_kv=None, cp_axis=None, cp=1, ring_overlap=True):
    """Returns (out, new_kv) where new_kv = {"k","v"} of THIS chunk (for the
    ChunkFlow state store).

    prefix: optional {"k","v","pos","seg"} of earlier chunks — prepended to
    this chunk's K/V (the paper's StateStore read path).
    cross_kv: optional {"k","v","seg"} for encoder-decoder cross attention
    (used instead of self-attention K/V; no causal mask).
    cp_axis/cp: context parallelism — set inside a ``shard_map`` over a
    mesh axis of size ``cp`` where x/positions/segment_ids hold this rank's
    token shard and ``prefix`` this rank's slice of the (seq-sharded)
    StateStore. Attention then runs as a ppermute ring over ``cp_axis``
    (kernels.ops.ring_chunk_attention) and new_kv is the local shard.
    ring_overlap: double-buffer the ring (next hop's ppermute under the
    current hop's kernel) — numerically identical either way.
    """
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, T, cfg.padded_num_heads, hd)

    if cross_kv is not None:
        k, v = cross_kv["k"], cross_kv["v"]
        mask = make_attention_mask(
            jnp.zeros_like(segment_ids), jnp.zeros_like(cross_kv["seg"]),
            segment_ids, cross_kv["seg"], causal=False)
        out = sdpa(q, k, v, mask, attn_softcap=cfg.attn_softcap)
        out = out.reshape(B, T, cfg.padded_num_heads * hd) @ p["wo"]
        return out, None

    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, T, cfg.padded_num_kv_heads, hd)
    v = v.reshape(B, T, cfg.padded_num_kv_heads, hd)

    if cfg.rope_theta:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
            pos1d = positions[..., 0]
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            pos1d = positions
    else:
        pos1d = positions if positions.ndim == 2 else positions[..., 0]

    new_kv = {"k": k, "v": v}

    if prefix is not None:
        k_all = jnp.concatenate([prefix["k"], k], axis=1)
        v_all = jnp.concatenate([prefix["v"], v], axis=1)
        k_pos = jnp.concatenate([prefix["pos"], pos1d], axis=1)
        k_seg = jnp.concatenate([prefix["seg"], segment_ids], axis=1)
    else:
        k_all, v_all, k_pos, k_seg = k, v, pos1d, segment_ids

    # Backend ladder: CP ring (inside shard_map) -> pallas flash kernel
    # (trainable custom_vjp; window rides as a dynamic scalar so local/global
    # alternation shares one compile) -> dense sdpa for short sequences ->
    # blockwise online-softmax for long.
    Tk = k_all.shape[1]
    if cp_axis is not None and cp > 1:
        from repro.kernels import ops
        out = ops.ring_chunk_attention(
            q, k_all, v_all, pos1d, k_pos, segment_ids, k_seg,
            axis_name=cp_axis, cp=cp, window=window,
            softcap=cfg.attn_softcap,
            interpret=(cfg.attn_backend != "pallas"),
            overlap=ring_overlap)
    elif cfg.attn_backend in ("pallas", "pallas_interpret"):
        from repro.kernels import ops
        out = ops.chunk_attention(
            q, k_all, v_all, pos1d, k_pos, segment_ids, k_seg,
            window=window, softcap=cfg.attn_softcap, block_q=min(128, T),
            block_k=min(128, Tk),
            interpret=(cfg.attn_backend == "pallas_interpret"))
    elif max(T, Tk) <= blockwise_threshold:
        mask = make_attention_mask(pos1d, k_pos, segment_ids, k_seg,
                                   causal=True, window=window)
        out = sdpa(q, k_all, v_all, mask, attn_softcap=cfg.attn_softcap)
    else:
        qb = min(1024, T)
        kb = min(1024, Tk)

        def mask_fn(qi, ki):
            qp = jax.lax.dynamic_slice_in_dim(pos1d, qi, qb, 1)
            qs = jax.lax.dynamic_slice_in_dim(segment_ids, qi, qb, 1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, ki, kb, 1)
            ks_ = jax.lax.dynamic_slice_in_dim(k_seg, ki, kb, 1)
            return make_attention_mask(qp, kp, qs, ks_, causal=True, window=window)

        # causal triangle skipping: q block qi never attends past global
        # position P + (qi+1)*qb, so later kv blocks are statically dead
        P = k_all.shape[1] - T
        nk = Tk // kb
        kv_limits = [min(nk, -(-(P + (qi + 1) * qb) // kb))
                     for qi in range(T // qb)]
        out = blockwise_sdpa(q, k_all, v_all, mask_fn, q_block=qb, kv_block=kb,
                             attn_softcap=cfg.attn_softcap,
                             kv_limits=kv_limits)

    out = out.reshape(B, T, cfg.padded_num_heads * hd) @ p["wo"]
    return out, new_kv


# -------------------------------------------------------------------- MLP ---
def init_mlp(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
    }


def swiglu_mlp(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_gelu_mlp(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 2)
    return {
        "w_in": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p, x):
    return jax.nn.gelu((x @ p["w_in"]) + p["b_in"]) @ p["w_out"] + p["b_out"]


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x
