"""Unified model API for all assigned architecture families.

Contract (the ChunkFlow state protocol, DESIGN.md §4):

    init_params(cfg, key, max_seq)            -> params pytree
    forward(cfg, params, batch, state=None)   -> (logits, new_state, aux)
    init_decode_cache(cfg, batch, max_seq)    -> cache pytree
    decode_step(cfg, params, cache, tokens, cache_len, ...) -> (logits, cache)

``state`` carries what a *later chunk of the same sequence* needs from earlier
chunks: per-layer K/V (+ their positions/segments) for attention layers, the
SSD recurrent state + conv tail for mamba layers, the encoder output for
enc-dec. ``forward`` both consumes and extends it, so the ChunkFlow scheduler
(core/chunked_step.py) can thread it through Algorithm 2.

batch keys: tokens (B,T) int32; segment_ids (B,T) int32 (0 = pad);
positions (B,T) int32 — or (B,T,3) for M-RoPE; encoder_embeds (B,Se,D) for
audio; patch_embeds (B,Np,D) for vlm.

Layers are scanned with stacked params so the HLO stays small for 61–80 layer
configs (compile-time matters: the dry-run lowers these on one CPU core).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2, moe as moe_lib

BIG_WINDOW = 1 << 30
VOCAB_PAD_UNIT = 256          # Megatron-style vocab padding (TP divisibility)
VOCAB_PAD_MIN = 1024          # only pad production-sized vocabs


def padded_vocab(cfg: ModelConfig) -> int:
    v = cfg.vocab_size
    if v < VOCAB_PAD_MIN:
        return v
    return -(-v // VOCAB_PAD_UNIT) * VOCAB_PAD_UNIT


# ============================================================== param init ===
def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def _init_decoder_layer(cfg: ModelConfig, dtype):
    def f(key):
        ks = jax.random.split(key, 4)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
        }
        if cfg.num_experts:
            p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        return p
    return f


def _layer_windows(cfg: ModelConfig) -> np.ndarray:
    if cfg.sliding_window and cfg.local_global_alternate:
        return np.array([cfg.sliding_window if i % 2 == 0 else BIG_WINDOW
                         for i in range(cfg.num_layers)], np.int32)
    if cfg.sliding_window:
        return np.full((cfg.num_layers,), cfg.sliding_window, np.int32)
    return np.full((cfg.num_layers,), BIG_WINDOW, np.int32)


def init_params(cfg: ModelConfig, key, max_seq: int = 4096):
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg)
    ks = jax.random.split(key, 8)
    if cfg.family in ("dense", "moe", "vlm"):
        p = {
            "embed": L.dense_init(ks[0], (vp, cfg.d_model), dtype=dtype),
            "layers": _stack_init(_init_decoder_layer(cfg, dtype), ks[1],
                                  cfg.num_layers),
            "ln_f": jnp.zeros((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = L.dense_init(ks[2], (cfg.d_model, vp),
                                        dtype=dtype)
        return p

    if cfg.family == "ssm":
        return {
            "embed": L.dense_init(ks[0], (vp, cfg.d_model), dtype=dtype),
            "layers": _stack_init(
                lambda k: {"ln": jnp.zeros((cfg.d_model,), dtype),
                           "mamba": mamba2.init_mamba(k, cfg, dtype)},
                ks[1], cfg.num_layers),
            "ln_f": jnp.zeros((cfg.d_model,), dtype),
            "unembed": L.dense_init(ks[2], (cfg.d_model, vp),
                                    dtype=dtype),
        }

    if cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.attn_every
        nm = cfg.attn_every - 1          # mamba sublayers per block

        def block(key):
            bk = jax.random.split(key, 6)
            return {
                "mamba": _stack_init(
                    lambda k: {"ln": jnp.zeros((cfg.d_model,), dtype),
                               "mamba": mamba2.init_mamba(k, cfg, dtype)},
                    bk[0], nm),
                "moe_m": _stack_init(
                    lambda k: {"ln": jnp.zeros((cfg.d_model,), dtype),
                               "moe": moe_lib.init_moe(k, cfg, dtype)},
                    bk[1], nm),
                "attn": {"ln": jnp.zeros((cfg.d_model,), dtype),
                         "attn": L.init_attention(bk[2], cfg, dtype)},
                "moe_a": {"ln": jnp.zeros((cfg.d_model,), dtype),
                          "moe": moe_lib.init_moe(bk[3], cfg, dtype)},
            }

        return {
            "embed": L.dense_init(ks[0], (vp, cfg.d_model), dtype=dtype),
            "blocks": _stack_init(block, ks[1], nb),
            "ln_f": jnp.zeros((cfg.d_model,), dtype),
            "unembed": L.dense_init(ks[2], (cfg.d_model, vp),
                                    dtype=dtype),
        }

    if cfg.family == "audio":
        def enc_layer(key):
            kk = jax.random.split(key, 2)
            return {
                "ln1_w": jnp.ones((cfg.d_model,), dtype),
                "ln1_b": jnp.zeros((cfg.d_model,), dtype),
                "ln2_w": jnp.ones((cfg.d_model,), dtype),
                "ln2_b": jnp.zeros((cfg.d_model,), dtype),
                "attn": L.init_attention(kk[0], cfg, dtype),
                "mlp": L.init_gelu_mlp(kk[1], cfg.d_model, cfg.d_ff, dtype),
            }

        def dec_layer(key):
            kk = jax.random.split(key, 3)
            return {
                "ln1_w": jnp.ones((cfg.d_model,), dtype),
                "ln1_b": jnp.zeros((cfg.d_model,), dtype),
                "ln2_w": jnp.ones((cfg.d_model,), dtype),
                "ln2_b": jnp.zeros((cfg.d_model,), dtype),
                "ln3_w": jnp.ones((cfg.d_model,), dtype),
                "ln3_b": jnp.zeros((cfg.d_model,), dtype),
                "self_attn": L.init_attention(kk[0], cfg, dtype),
                "cross_attn": L.init_attention(kk[1], cfg, dtype),
                "mlp": L.init_gelu_mlp(kk[2], cfg.d_model, cfg.d_ff, dtype),
            }

        return {
            "enc_pos": L.dense_init(ks[0], (cfg.encoder_seq, cfg.d_model),
                                    dtype=dtype),
            "enc_layers": _stack_init(enc_layer, ks[1], cfg.encoder_layers),
            "enc_ln_f_w": jnp.ones((cfg.d_model,), dtype),
            "enc_ln_f_b": jnp.zeros((cfg.d_model,), dtype),
            "embed": L.dense_init(ks[2], (vp, cfg.d_model), dtype=dtype),
            "dec_pos": L.dense_init(ks[3], (max_seq, cfg.d_model), dtype=dtype),
            "dec_layers": _stack_init(dec_layer, ks[4], cfg.num_layers),
            "dec_ln_f_w": jnp.ones((cfg.d_model,), dtype),
            "dec_ln_f_b": jnp.zeros((cfg.d_model,), dtype),
        }

    raise ValueError(f"unknown family {cfg.family}")


# ============================================================ empty states ===
def empty_state(cfg: ModelConfig, batch: int, dtype=None, capacity: int = 0):
    """Empty chunk state — lets forward() use one code path.

    ``capacity`` pre-allocates the K/V (and pos/seg) length: the static-shape
    StateStore hands every chunk of a group the same capacity-padded prefix
    (unused slots keep seg=0 and are exactly masked out of attention), so the
    jitted chunk step compiles once per capacity bucket instead of once per
    chunk index. capacity=0 is the classic zero-length state."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim

    def attn_state(n_layers):
        return {
            "k": jnp.zeros((n_layers, batch, capacity,
                            cfg.padded_num_kv_heads, hd), dtype),
            "v": jnp.zeros((n_layers, batch, capacity,
                            cfg.padded_num_kv_heads, hd), dtype),
            "pos": jnp.zeros((batch, capacity), jnp.int32),
            "seg": jnp.zeros((batch, capacity), jnp.int32),
        }

    def mamba_state(shape_prefix):
        G = 1
        conv_dim = cfg.d_inner + 2 * G * cfg.ssm_state
        return {
            "ssm": jnp.zeros(shape_prefix + (batch, cfg.ssm_heads,
                                             cfg.ssm_head_dim, cfg.ssm_state),
                             jnp.float32),
            "conv": jnp.zeros(shape_prefix + (batch, cfg.ssm_conv_width - 1,
                                              conv_dim), jnp.float32),
        }

    if cfg.family in ("dense", "moe", "vlm"):
        return attn_state(cfg.num_layers)
    if cfg.family == "ssm":
        return mamba_state((cfg.num_layers,))
    if cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.attn_every
        return {"attn": attn_state(nb),
                "mamba": mamba_state((nb, cfg.attn_every - 1))}
    if cfg.family == "audio":
        st = attn_state(cfg.num_layers)
        st["enc_out"] = None    # filled by the first chunk's encoder pass
        return st
    raise ValueError(cfg.family)


# ================================================================= forward ===
def forward(cfg: ModelConfig, params, batch, state=None,
            blockwise_threshold: int = 8192, remat: bool = False):
    tokens = batch["tokens"]
    B, T = tokens.shape
    seg = batch.get("segment_ids")
    if seg is None:
        seg = jnp.ones((B, T), jnp.int32)
    pos = batch.get("positions")
    if pos is None:
        base = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
        pos = (jnp.stack([base] * 3, axis=-1) if cfg.mrope else base)
    if state is None:
        state = empty_state(cfg, B)

    if cfg.family in ("dense", "moe", "vlm"):
        return _decoder_forward(cfg, params, tokens, seg, pos, batch, state,
                                blockwise_threshold, remat)
    if cfg.family == "ssm":
        return _ssm_forward(cfg, params, tokens, seg, state, remat)
    if cfg.family == "hybrid":
        return _hybrid_forward(cfg, params, tokens, seg, pos, state,
                               blockwise_threshold, remat)
    if cfg.family == "audio":
        return _audio_forward(cfg, params, tokens, seg, pos, batch, state,
                              remat)
    raise ValueError(cfg.family)


def _unembed(cfg, params, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["unembed"]
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = L.softcap(logits, cfg.logit_softcap)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab_size:
        mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def _decoder_forward(cfg, params, tokens, seg, pos, batch, state, bwt,
                     remat=False):
    B, T = tokens.shape
    x = params["embed"][tokens]
    if cfg.family == "vlm" and batch.get("patch_embeds") is not None:
        npatch = batch["patch_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(x.dtype), x[:, npatch:]], axis=1)

    windows = jnp.asarray(_layer_windows(cfg))

    def layer_fn(carry, xs):
        x, aux = carry
        lp, window, pk, pv = xs
        prefix = {"k": pk, "v": pv, "pos": state["pos"], "seg": state["seg"]}
        h, new_kv = L.attention_layer(
            lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            positions=pos, segment_ids=seg, prefix=prefix, window=window,
            blockwise_threshold=bwt)
        x = x + h
        xn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.num_experts:
            h2, a = moe_lib.moe_layer(lp["moe"], xn, cfg)
            aux = aux + a
        else:
            h2 = L.swiglu_mlp(lp["mlp"], xn)
        return (x + h2, aux), new_kv

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    (x, aux), new_kvs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], windows, state["k"], state["v"]))

    pos1d = pos[..., 0] if cfg.mrope else pos
    new_state = {
        "k": jnp.concatenate([state["k"], new_kvs["k"]], axis=2),
        "v": jnp.concatenate([state["v"], new_kvs["v"]], axis=2),
        "pos": jnp.concatenate([state["pos"], pos1d], axis=1),
        "seg": jnp.concatenate([state["seg"], seg], axis=1),
    }
    logits = _unembed(cfg, params, L.rms_norm(x, params["ln_f"], cfg.norm_eps))
    return logits, new_state, {"moe_aux": aux}


def _ssm_forward(cfg, params, tokens, seg, state, remat=False):
    x = params["embed"][tokens]

    def layer_fn(x, xs):
        lp, st = xs
        h, new_st = mamba2.mamba_layer(lp["mamba"],
                                       L.rms_norm(x, lp["ln"], cfg.norm_eps),
                                       cfg, state=st, segment_ids=seg)
        return x + h, new_st

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    x, new_states = jax.lax.scan(body, x, (params["layers"], state))
    logits = _unembed(cfg, params, L.rms_norm(x, params["ln_f"], cfg.norm_eps))
    return logits, new_states, {"moe_aux": jnp.zeros((), jnp.float32)}


def _hybrid_forward(cfg, params, tokens, seg, pos, state, bwt,
                    remat=False):
    x = params["embed"][tokens]

    def block_fn(carry, xs):
        x, aux = carry
        bp, m_st, pk, pv = xs

        def sub_fn(carry, sub_xs):
            x, aux = carry
            mp, op, st = sub_xs
            h, new_st = mamba2.mamba_layer(
                mp["mamba"], L.rms_norm(x, mp["ln"], cfg.norm_eps), cfg,
                state=st, segment_ids=seg)
            x = x + h
            h2, a = moe_lib.moe_layer(
                op["moe"], L.rms_norm(x, op["ln"], cfg.norm_eps), cfg)
            return (x + h2, aux + a), new_st

        (x, aux), new_m_st = jax.lax.scan(
            sub_fn, (x, aux), (bp["mamba"], bp["moe_m"], m_st))

        prefix = {"k": pk, "v": pv, "pos": state["attn"]["pos"],
                  "seg": state["attn"]["seg"]}
        h, new_kv = L.attention_layer(
            bp["attn"]["attn"],
            L.rms_norm(x, bp["attn"]["ln"], cfg.norm_eps), cfg,
            positions=pos, segment_ids=seg, prefix=prefix,
            blockwise_threshold=bwt)
        x = x + h
        h2, a = moe_lib.moe_layer(
            bp["moe_a"]["moe"],
            L.rms_norm(x, bp["moe_a"]["ln"], cfg.norm_eps), cfg)
        return (x + h2, aux + a), (new_m_st, new_kv)

    block_body = jax.checkpoint(block_fn) if remat else block_fn
    (x, aux), (new_m, new_kvs) = jax.lax.scan(
        block_body, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], state["mamba"], state["attn"]["k"],
         state["attn"]["v"]))

    new_state = {
        "attn": {
            "k": jnp.concatenate([state["attn"]["k"], new_kvs["k"]], axis=2),
            "v": jnp.concatenate([state["attn"]["v"], new_kvs["v"]], axis=2),
            "pos": jnp.concatenate([state["attn"]["pos"], pos], axis=1),
            "seg": jnp.concatenate([state["attn"]["seg"], seg], axis=1),
        },
        "mamba": new_m,
    }
    logits = _unembed(cfg, params, L.rms_norm(x, params["ln_f"], cfg.norm_eps))
    return logits, new_state, {"moe_aux": aux}


def encode_audio(cfg, params, encoder_embeds):
    """Whisper encoder over stub frame embeddings (B, Se, D)."""
    x = encoder_embeds.astype(params["enc_pos"].dtype) + params["enc_pos"][None]
    B, Se, _ = x.shape
    ones = jnp.ones((B, Se), jnp.int32)
    zeros = jnp.zeros((B, Se), jnp.int32)

    def layer_fn(x, lp):
        xn = L.layer_norm(x, lp["ln1_w"], lp["ln1_b"])
        mask = L.make_attention_mask(zeros, zeros, ones, ones, causal=False)
        hd = cfg.resolved_head_dim
        q = (xn @ lp["attn"]["wq"]).reshape(B, Se, cfg.padded_num_heads, hd)
        k = (xn @ lp["attn"]["wk"]).reshape(B, Se, cfg.padded_num_kv_heads, hd)
        v = (xn @ lp["attn"]["wv"]).reshape(B, Se, cfg.padded_num_kv_heads, hd)
        h = L.sdpa(q, k, v, mask).reshape(B, Se, cfg.padded_num_heads * hd)
        x = x + h @ lp["attn"]["wo"]
        xn = L.layer_norm(x, lp["ln2_w"], lp["ln2_b"])
        return x + L.gelu_mlp(lp["mlp"], xn), None

    x, _ = jax.lax.scan(layer_fn, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_ln_f_w"], params["enc_ln_f_b"])


def _audio_forward(cfg, params, tokens, seg, pos, batch, state,
                   remat=False):
    B, T = tokens.shape
    hd = cfg.resolved_head_dim
    enc_out = state.get("enc_out")
    if enc_out is None:
        enc_out = encode_audio(cfg, params, batch["encoder_embeds"])
    Se = enc_out.shape[1]
    enc_seg = jnp.ones((B, Se), jnp.int32)

    x = params["embed"][tokens] + params["dec_pos"][pos]

    def layer_fn(x, xs):
        lp, pk, pv = xs
        prefix = {"k": pk, "v": pv, "pos": state["pos"], "seg": state["seg"]}
        xn = L.layer_norm(x, lp["ln1_w"], lp["ln1_b"])
        h, new_kv = L.attention_layer(lp["self_attn"], xn, cfg, positions=pos,
                                      segment_ids=seg, prefix=prefix)
        x = x + h
        # cross attention
        xn = L.layer_norm(x, lp["ln2_w"], lp["ln2_b"])
        ck = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, Se, cfg.padded_num_kv_heads, hd)
        cv = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, Se, cfg.padded_num_kv_heads, hd)
        h, _ = L.attention_layer(lp["cross_attn"], xn, cfg, positions=pos,
                                 segment_ids=seg,
                                 cross_kv={"k": ck, "v": cv, "seg": enc_seg})
        x = x + h
        xn = L.layer_norm(x, lp["ln3_w"], lp["ln3_b"])
        return x + L.gelu_mlp(lp["mlp"], xn), new_kv

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    x, new_kvs = jax.lax.scan(body, x, (params["dec_layers"], state["k"],
                                        state["v"]))
    new_state = {
        "k": jnp.concatenate([state["k"], new_kvs["k"]], axis=2),
        "v": jnp.concatenate([state["v"], new_kvs["v"]], axis=2),
        "pos": jnp.concatenate([state["pos"], pos], axis=1),
        "seg": jnp.concatenate([state["seg"], seg], axis=1),
        "enc_out": enc_out,
    }
    x = L.layer_norm(x, params["dec_ln_f_w"], params["dec_ln_f_b"])
    logits = (x @ params["embed"].T).astype(jnp.float32)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab_size:
        logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, -1e30)
    return logits, new_state, {"moe_aux": jnp.zeros((), jnp.float32)}
