"""Msgpack pytree checkpointing (params + optimizer state + step)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack(obj):
    leaves, treedef = jax.tree.flatten(obj)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.asarray(l).shape),
             "data": np.ascontiguousarray(
                 np.asarray(l).astype(
                     np.float32 if np.asarray(l).dtype == jnp.bfloat16
                     else np.asarray(l).dtype)).tobytes()}
            for l in leaves
        ],
    }
    return payload


def save_checkpoint(path: str, tree, step: int = 0):
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb({"step": step, "tree": _pack(tree)}))
    os.replace(tmp, path)


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with open(path, "rb") as f:
        blob = msgpack.unpackb(f.read())
    leaves, treedef = jax.tree.flatten(like)
    stored = blob["tree"]["leaves"]
    assert len(stored) == len(leaves), (len(stored), len(leaves))
    out = []
    for ref, s in zip(leaves, stored):
        dt = np.float32 if s["dtype"] == "bfloat16" else np.dtype(s["dtype"])
        arr = np.frombuffer(s["data"], dtype=dt).reshape(s["shape"])
        assert tuple(arr.shape) == tuple(np.asarray(ref).shape), \
            (arr.shape, np.asarray(ref).shape)
        out.append(jnp.asarray(arr, dtype=np.asarray(ref).dtype))
    return jax.tree.unflatten(treedef, out), blob["step"]
