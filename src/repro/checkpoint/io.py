"""Msgpack pytree checkpointing (params + optimizer state + step)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack(obj):
    leaves, treedef = jax.tree.flatten(obj)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.asarray(l).shape),
             "data": np.ascontiguousarray(
                 np.asarray(l).astype(
                     np.float32 if np.asarray(l).dtype == jnp.bfloat16
                     else np.asarray(l).dtype)).tobytes()}
            for l in leaves
        ],
    }
    return payload


def save_checkpoint(path: str, tree, step: int = 0):
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb({"step": step, "tree": _pack(tree)}))
    os.replace(tmp, path)


def _treedef_diff(stored: str, expected: str) -> str:
    """Point at the first divergence between two treedef reprs — two trees
    with the SAME leaf count can differ only in structure, and restoring
    across that silently fills the wrong slots."""
    n = next((i for i, (a, b) in enumerate(zip(stored, expected)) if a != b),
             min(len(stored), len(expected)))
    ctx = 40
    return (f"first divergence at char {n}:\n"
            f"  stored:    ...{stored[max(0, n - ctx):n + ctx]}...\n"
            f"  restoring: ...{expected[max(0, n - ctx):n + ctx]}...")


def restore_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (treedef, shapes and dtypes
    validated — a structure mismatch raises instead of silently restoring
    leaves into the wrong slots)."""
    with open(path, "rb") as f:
        blob = msgpack.unpackb(f.read())
    leaves, treedef = jax.tree.flatten(like)
    stored_td = blob["tree"].get("treedef")
    if stored_td is not None and stored_td != str(treedef):
        raise ValueError(
            f"checkpoint treedef mismatch in {path!r}: the stored pytree "
            "structure differs from the restore target "
            f"({_treedef_diff(stored_td, str(treedef))})\n"
            f"  stored treedef:    {stored_td}\n"
            f"  restore-target:    {treedef}")
    stored = blob["tree"]["leaves"]
    assert len(stored) == len(leaves), (len(stored), len(leaves))
    out = []
    for ref, s in zip(leaves, stored):
        dt = np.float32 if s["dtype"] == "bfloat16" else np.dtype(s["dtype"])
        arr = np.frombuffer(s["data"], dtype=dt).reshape(s["shape"])
        assert tuple(arr.shape) == tuple(np.asarray(ref).shape), \
            (arr.shape, np.asarray(ref).shape)
        out.append(jnp.asarray(arr, dtype=np.asarray(ref).dtype))
    return jax.tree.unflatten(treedef, out), blob["step"]
