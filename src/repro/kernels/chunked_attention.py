"""Pallas TPU kernel: chunked prefix-KV flash attention.

This is ChunkFlow's compute hot-spot: a query chunk of T tokens attends to
(prefix KV of earlier chunks) ++ (its own KV, causally). One fused kernel
handles both the standalone-packed case (segment-masked, prefix len 0) and
the dependent-chunk case (prefix + causal), so the chunk scheduler never pays
two attention launches.

TPU mapping (DESIGN.md §2): grid (B, Hq, nQ, nK) with the kv axis innermost
and sequential ("arbitrary") so the online-softmax running max / denominator
/ accumulator live in VMEM scratch across kv steps; q/k/v blocks are
BlockSpec-tiled into VMEM with MXU-aligned (128-multiple) block shapes; the
two matmuls hit the MXU at f32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qpos_ref, kpos_ref, qseg_ref, kseg_ref,
                  q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale, window, softcap, n_k):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    qp = qpos_ref[0][:, None]                      # (bq, 1)
    kp = kpos_ref[0][None, :]                      # (1, bk)
    qs = qseg_ref[0][:, None]
    ks = kseg_ref[0][None, :]
    mask = (qs == ks) & (qs > 0) & (ks > 0) & (qp >= kp)
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ik == n_k - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def chunked_prefix_attention(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                             window: int = 0, softcap: float = 0.0,
                             block_q: int = 128, block_k: int = 128,
                             interpret: bool = False):
    """q: (B, Hq, T, D); k/v: (B, Hkv, S, D) where S = prefix_len + T.
    q_pos/q_seg: (B, T); k_pos/k_seg: (B, S). Returns (B, Hq, T, D).

    Callers must pad T to block_q and S to block_k (pad slots get seg=0).
    """
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    G = Hq // Hkv
    n_q, n_k = T // block_q, S // block_k
    grid = (B, Hq, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (D ** 0.5), window=window,
        softcap=softcap, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_pos, k_pos, q_seg, k_seg, q, k, v)
