"""Pallas TPU kernels: chunked prefix-KV flash attention, forward + backward.

This is ChunkFlow's compute hot-spot: a query chunk of T tokens attends to
(prefix KV of earlier chunks) ++ (its own KV, causally). One fused kernel
handles both the standalone-packed case (segment-masked, prefix len 0) and
the dependent-chunk case (prefix + causal), so the chunk scheduler never pays
two attention launches.

The public entry point ``chunked_prefix_attention`` is *trainable*: it is
wrapped in ``jax.custom_vjp`` with fused Pallas backward kernels
(``_flash_bwd_dq_kernel`` / ``_flash_bwd_dkv_kernel``), so ``jax.vjp`` in the
Algorithm-2 executor differentiates straight through the flash kernel instead
of falling back to the dense sdpa path. The forward emits the standard
softmax log-sum-exp residual; the backward recomputes P tiles from (q, k,
lse) flash-attention style — no (T, S) score matrix is ever materialised in
either direction.

TPU mapping (DESIGN.md §2): forward + dq grids are (B, Hq, nQ, nK) with the
kv axis innermost and sequential ("arbitrary") so the online-softmax running
max / denominator / accumulator (resp. the dq accumulator) live in VMEM
scratch across kv steps; the dkv grid is (B, Hkv, nK, G*nQ) with the fused
(group-head, q-block) axis innermost so dk/dv accumulate over every query
block *and* every GQA head that reads the kv block. q/k/v blocks are
BlockSpec-tiled into VMEM with MXU-aligned (128-multiple) block shapes; all
matmuls hit the MXU at f32 accumulation regardless of input dtype.

Mask contract (shared by fwd and bwd): packed segments (seg == 0 is padding,
never attends/attended), causality on global positions, and an optional
sliding window. The window rides as a *dynamic* SMEM scalar so per-layer
local/global alternation (a traced window under ``lax.scan``) hits one
compiled kernel; ``window <= 0`` disables it and BIG_WINDOW-style sentinels
are no-ops.

``ring_chunked_prefix_attention`` is the context-parallel sibling: the same
fwd/bwd kernels run per ring hop inside a ``shard_map`` over the "seq" mesh
axis while K/V (with its pos/seg metadata) circulates via ``lax.ppermute``;
partials merge through the LSE residual, and the backward exploits the flash
decomposition (per-hop dq/dk/dv depend only on the global LSE and delta) so
the Pallas kernels are reused unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_block(qpos_ref, kpos_ref, qseg_ref, kseg_ref, w_ref):
    """(bq, bk) bool mask from the pos/seg block refs + dynamic window."""
    qp = qpos_ref[0][:, None]
    kp = kpos_ref[0][None, :]
    qs = qseg_ref[0][:, None]
    ks = kseg_ref[0][None, :]
    w = w_ref[0]
    mask = (qs == ks) & (qs > 0) & (ks > 0) & (qp >= kp)
    return mask & ((w <= 0) | ((qp - kp) < w))


def _softcapped(s, softcap):
    """Returns (scores, tanh) — tanh is reused by the backward chain rule."""
    if not softcap:
        return s, None
    t = jnp.tanh(s / softcap)
    return softcap * t, t


# ================================================================ forward ====
def _flash_fwd_kernel(w_ref, qpos_ref, kpos_ref, qseg_ref, kseg_ref,
                      q_ref, k_ref, v_ref, o_ref, lse_ref,
                      m_scr, l_scr, acc_scr, *, scale, softcap, n_k):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s, _ = _softcapped(s, softcap)
    mask = _mask_block(qpos_ref, kpos_ref, qseg_ref, kseg_ref, w_ref)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ik == n_k - 1)
    def _flush():
        m, l = m_scr[...], l_scr[...]
        # fully-masked rows (padding queries / unused capacity slots): zero
        # output like the ref, and an LSE sentinel the backward maps to p=0.
        valid = m > NEG_INF / 2
        denom = jnp.maximum(l, 1e-30)[:, None]
        o = jnp.where(valid[:, None], acc_scr[...] / denom, 0.0)
        o_ref[0, 0, :, :] = o.astype(o_ref.dtype)
        lse_ref[0, 0, :] = jnp.where(valid, m + jnp.log(jnp.maximum(l, 1e-30)),
                                     NEG_INF)


def _flash_fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w, *, softcap, block_q,
               block_k, interpret):
    """Returns (o, lse); lse is the f32 (B, Hq, T) softmax residual."""
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    G = Hq // Hkv
    n_q, n_k = T // block_q, S // block_k
    grid = (B, Hq, n_q, n_k)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=1.0 / (D ** 0.5), softcap=softcap, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, T), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(w, q_pos, k_pos, q_seg, k_seg, q, k, v)


# =============================================================== backward ====
def _p_and_ds(q, k, v, do, lse, delta, mask, *, scale, softcap):
    """Recompute the probability tile and the score cotangent for one
    (q-block, kv-block) pair. Shared by the dq and dkv kernels so the two
    stay bit-identical on the mask/softcap contract."""
    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    s, t = _softcapped(s_raw, softcap)
    # p = exp(s - lse) on valid entries, exactly 0 elsewhere (incl. rows whose
    # lse is the fully-masked sentinel: mask is False there too).
    p = jnp.exp(jnp.where(mask, s - lse[:, None], NEG_INF))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    if softcap:
        ds = ds * (1.0 - t * t)
    return p, ds


def _flash_bwd_dq_kernel(w_ref, qpos_ref, kpos_ref, qseg_ref, kseg_ref,
                         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc_scr, *, scale, softcap, n_k):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    mask = _mask_block(qpos_ref, kpos_ref, qseg_ref, kseg_ref, w_ref)
    _, ds = _p_and_ds(q, k, v, do, lse_ref[0, 0], delta_ref[0, 0], mask,
                      scale=scale, softcap=softcap)
    acc_scr[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(ik == n_k - 1)
    def _flush():
        dq_ref[0, 0, :, :] = acc_scr[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(w_ref, qpos_ref, kpos_ref, qseg_ref, kseg_ref,
                          q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_scr, dv_scr, *,
                          scale, softcap, n_qh):
    t = pl.program_id(3)           # fused (GQA head-in-group, q block) axis

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    mask = _mask_block(qpos_ref, kpos_ref, qseg_ref, kseg_ref, w_ref)
    p, ds = _p_and_ds(q, k, v, do, lse_ref[0, 0], delta_ref[0, 0], mask,
                      scale=scale, softcap=softcap)
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(t == n_qh - 1)
    def _flush():
        dk_ref[0, 0, :, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0, :, :] = dv_scr[...].astype(dv_ref.dtype)


def _flash_bwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w, do, lse, delta, *,
               softcap, block_q, block_k, interpret):
    B, Hq, T, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    n_q, n_k = T // block_q, S // block_k
    scale = 1.0 / (D ** 0.5)

    pos_seg_specs = lambda qmap, kmap: [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((1, block_q), qmap),
        pl.BlockSpec((1, block_k), kmap),
        pl.BlockSpec((1, block_q), qmap),
        pl.BlockSpec((1, block_k), kmap),
    ]

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale, softcap=softcap,
                          n_k=n_k),
        grid=(B, Hq, n_q, n_k),
        in_specs=pos_seg_specs(lambda b, h, iq, ik: (b, iq),
                               lambda b, h, iq, ik: (b, ik)) + [
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(w, q_pos, k_pos, q_seg, k_seg, q, k, v, do, lse, delta)

    # dk/dv: one kv block accumulates over the fused (group head, q block)
    # innermost axis t = g * n_q + iq, i.e. every reader of this kv block.
    n_qh = G * n_q
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, scale=scale, softcap=softcap,
                          n_qh=n_qh),
        grid=(B, Hkv, n_k, n_qh),
        in_specs=pos_seg_specs(lambda b, h, ik, t: (b, t % n_q),
                               lambda b, h, ik, t: (b, ik)) + [
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, ik, t: (b, h * G + t // n_q, t % n_q, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, t: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, t: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, ik, t: (b, h * G + t // n_q, t % n_q, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, ik, t: (b, h * G + t // n_q, t % n_q)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b, h, ik, t: (b, h * G + t // n_q, t % n_q)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, t: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, t: (b, h, ik, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)),
        scratch_shapes=[pltpu.VMEM((block_k, D), jnp.float32),
                        pltpu.VMEM((block_k, D), jnp.float32)],
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(w, q_pos, k_pos, q_seg, k_seg, q, k, v, do, lse, delta)
    return dq, dk, dv


# ============================================================== custom_vjp ===
@functools.lru_cache(maxsize=None)
def _attention_fn(softcap: float, block_q: int, block_k: int,
                  interpret: bool):
    kw = dict(softcap=softcap, block_q=block_q, block_k=block_k,
              interpret=interpret)

    @jax.custom_vjp
    def attn(q, k, v, q_pos, k_pos, q_seg, k_seg, w):
        return _flash_fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w, **kw)[0]

    def fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w):
        o, lse = _flash_fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w, **kw)
        return o, (q, k, v, q_pos, k_pos, q_seg, k_seg, w, o, lse)

    def bwd(res, do):
        q, k, v, q_pos, k_pos, q_seg, k_seg, w, o, lse = res
        # delta_i = sum_j P_ij dP_ij = rowsum(do * o): the softmax-Jacobian
        # diagonal term, cheap elementwise preprocess outside the kernels.
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                        axis=-1)
        dq, dk, dv = _flash_bwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w, do,
                                lse, delta, **kw)
        return dq, dk, dv, None, None, None, None, None

    attn.defvjp(fwd, bwd)
    return attn


# ========================================================= ring (CP) path ===
def _merge_partials(o_a, lse_a, o_b, lse_b):
    """Online-softmax merge of two *normalized* flash partials (f32).

    Each partial is attention over a disjoint K/V subset with its own
    log-sum-exp; the merged pair is exactly attention over the union. Fully
    masked partials carry the LSE sentinel (~NEG_INF) and zero output, so
    their merge weight underflows to 0 and they drop out."""
    lse = jnp.logaddexp(lse_a, lse_b)
    w_a = jnp.exp(lse_a - lse)[..., None]
    w_b = jnp.exp(lse_b - lse)[..., None]
    return o_a * w_a + o_b * w_b, lse


@functools.lru_cache(maxsize=None)
def _ring_attention_fn(axis_name: str, cp: int, softcap: float, block_q: int,
                       block_k: int, interpret: bool, overlap: bool):
    """Ring flash attention over a ``shard_map`` axis of size ``cp``.

    Called with this rank's Q shard and K/V *ring shard*; the K/V (with its
    pos/seg metadata) circulates via ``lax.ppermute`` while Q stays resident.
    Forward: per-hop ``_flash_fwd`` partials merged with the LSE residual.
    Backward: the standard flash decomposition — dq/dk/dv for every
    (q-shard, kv-shard) pair depend only on the *global* LSE and
    delta = rowsum(do * o), so each hop reuses the existing ``_flash_bwd``
    Pallas kernels unchanged; the dk/dv accumulator travels WITH its kv
    shard around the ring and a final hop returns it to the owner.

    ``overlap`` double-buffers the ring (FlexSP §5): hop ``step+1``'s
    ppermute is ISSUED before hop ``step``'s flash kernel, so the neighbor
    collective has no data dependency on the kernel and XLA is free to run
    them concurrently. The hop order, merge order and accumulate order are
    identical to the serial schedule, so the result is numerically the
    same — only the dispatch order (and therefore the exposed comm time)
    changes. In the backward the K/V prefetch hoists the same way; the
    dk/dv accumulator rotation necessarily stays after the hop's
    accumulate (it consumes dk_h/dv_h), but nothing downstream blocks on
    it until the NEXT accumulate, so it overlaps the next kernel by
    dataflow."""
    kw = dict(softcap=softcap, block_q=block_q, block_k=block_k,
              interpret=interpret)
    perm = [(i, (i + 1) % cp) for i in range(cp)]

    def rotate(*xs):
        return tuple(jax.lax.ppermute(x, axis_name, perm) for x in xs)

    def ring_fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w):
        kc, vc, pc, sc = k, v, k_pos, k_seg
        o = lse = None
        for step in range(cp):
            nxt = (rotate(kc, vc, pc, sc)
                   if overlap and step < cp - 1 else None)
            o_h, lse_h = _flash_fwd(q, kc, vc, q_pos, pc, q_seg, sc, w, **kw)
            o_h = o_h.astype(jnp.float32)
            o, lse = ((o_h, lse_h) if o is None
                      else _merge_partials(o, lse, o_h, lse_h))
            if step < cp - 1:
                kc, vc, pc, sc = nxt if overlap else rotate(kc, vc, pc, sc)
        return o.astype(q.dtype), lse

    @jax.custom_vjp
    def attn(q, k, v, q_pos, k_pos, q_seg, k_seg, w):
        return ring_fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w)[0]

    def fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w):
        o, lse = ring_fwd(q, k, v, q_pos, k_pos, q_seg, k_seg, w)
        return o, (q, k, v, q_pos, k_pos, q_seg, k_seg, w, o, lse)

    def bwd(res, do):
        q, k, v, q_pos, k_pos, q_seg, k_seg, w, o, lse = res
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                        axis=-1)
        kc, vc, pc, sc = k, v, k_pos, k_seg
        dq = jnp.zeros(q.shape, jnp.float32)
        dk = jnp.zeros(k.shape, jnp.float32)
        dv = jnp.zeros(v.shape, jnp.float32)
        for step in range(cp):
            nxt = (rotate(kc, vc, pc, sc)
                   if overlap and step < cp - 1 else None)
            dq_h, dk_h, dv_h = _flash_bwd(q, kc, vc, q_pos, pc, q_seg, sc, w,
                                          do, lse, delta, **kw)
            dq += dq_h.astype(jnp.float32)
            dk += dk_h.astype(jnp.float32)
            dv += dv_h.astype(jnp.float32)
            if step < cp - 1:
                kc, vc, pc, sc = (nxt if overlap
                                  else rotate(kc, vc, pc, sc))
                dk, dv = rotate(dk, dv)
        dk, dv = rotate(dk, dv)      # return each accumulator to its owner
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                None, None, None, None, None)

    attn.defvjp(fwd, bwd)
    return attn


def ring_chunked_prefix_attention(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                                  axis_name: str, cp: int, window=0,
                                  softcap: float = 0.0, block_q: int = 128,
                                  block_k: int = 128,
                                  interpret: bool = False,
                                  overlap: bool = True):
    """Context-parallel chunked attention. MUST be called inside a
    ``shard_map`` over ``axis_name`` (size ``cp``): q is this rank's query
    shard (B, Hq, T/cp, D), k/v this rank's K/V ring shard (B, Hkv, S/cp, D)
    with matching k_pos/k_seg. Same mask contract and trainability as
    ``chunked_prefix_attention``; numerically equal to running the
    single-device kernel on the gathered shards (~1e-6, f32 merge order).
    ``overlap`` (default on) double-buffers the ring — hop i+1's ppermute
    issues before hop i's kernel; same hop/merge order, so exactness is
    unchanged (tests pin overlap-on == serial to the same tolerance)."""
    w = jnp.asarray(0 if window is None else window, jnp.int32).reshape(1)
    fn = _ring_attention_fn(str(axis_name), int(cp), float(softcap),
                            int(block_q), int(block_k), bool(interpret),
                            bool(overlap))
    return fn(q, k, v, q_pos, k_pos, q_seg, k_seg, w)


def chunked_prefix_attention(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                             window=0, softcap: float = 0.0,
                             block_q: int = 128, block_k: int = 128,
                             interpret: bool = False):
    """q: (B, Hq, T, D); k/v: (B, Hkv, S, D) where S = prefix_len + T (the
    prefix may be capacity-padded: unused slots carry seg=0 and are masked).
    q_pos/q_seg: (B, T); k_pos/k_seg: (B, S). Returns (B, Hq, T, D).

    Differentiable w.r.t. q/k/v via fused Pallas backward kernels. ``window``
    may be a Python int or a traced int scalar (<= 0 disables); softcap and
    block sizes are static. Callers must pad T to block_q and S to block_k
    (pad slots get seg=0; fully-masked query rows return zeros).
    """
    w = jnp.asarray(0 if window is None else window, jnp.int32).reshape(1)
    fn = _attention_fn(float(softcap), int(block_q), int(block_k),
                       bool(interpret))
    return fn(q, k, v, q_pos, k_pos, q_seg, k_seg, w)
