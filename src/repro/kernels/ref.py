"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunked_prefix_attention_ref(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                                 window: int = 0, softcap: float = 0.0,
                                 return_lse: bool = False):
    """Same contract as kernels.chunked_attention.chunked_prefix_attention.
    q: (B,Hq,T,D), k/v: (B,Hkv,S,D). The prefix span of k/v may be
    capacity-padded (seg=0 slots anywhere are masked out exactly).

    With ``return_lse`` also returns the f32 (B,Hq,T) log-sum-exp the flash
    forward emits as its backward residual (NEG_INF on fully-masked rows)."""
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, T, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qf, kf) / (D ** 0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask = ((q_seg[:, :, None] == k_seg[:, None, :])
            & (q_seg[:, :, None] > 0) & (k_seg[:, None, :] > 0)
            & (q_pos[:, :, None] >= k_pos[:, None, :]))
    if window:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (padding queries) -> zero output like the kernel
    any_valid = mask.any(axis=-1)[:, None, None, :, None]
    o = jnp.einsum("bhgts,bhsd->bhgtd", p, vf) * any_valid
    o = o.reshape(B, Hq, T, D).astype(q.dtype)
    if not return_lse:
        return o
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    lse = jnp.where(any_valid[..., 0], lse, NEG_INF)
    return o, lse.reshape(B, Hq, T)


def decode_attention_ref(q, k, v, cache_len, *, window: int = 0,
                         softcap: float = 0.0):
    """q: (B,Hq,1,D); k/v: (B,Hkv,S,D)."""
    B, Hq, _, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, 1, D)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qf, k.astype(jnp.float32)) / (D ** 0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    slot = jnp.arange(S)
    mask = slot <= cache_len
    if window:
        mask &= (cache_len - slot) < window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgts,bhsd->bhgtd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, 1, D).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, page_tables, cache_lens,
                               *, window: int = 0, softcap: float = 0.0):
    """Oracle for kernels.decode_attention.paged_decode_attention: gather the
    pages dense, then run per-request masked sdpa. q: (B,Hq,1,D);
    k/v_pages: (n_pages, page_size, Hkv, D); page_tables: (B, n_pages_per_req)
    int32; cache_lens: (B,) int32."""
    B, Hq, _, D = q.shape
    n_pages_per_req = page_tables.shape[1]
    page_size = k_pages.shape[1]
    Hkv = k_pages.shape[2]
    S = n_pages_per_req * page_size
    G = Hq // Hkv
    # (B, n_pages_per_req, page_size, Hkv, D) -> (B, Hkv, S, D)
    k = k_pages[page_tables].reshape(B, S, Hkv, D).transpose(0, 2, 1, 3)
    v = v_pages[page_tables].reshape(B, S, Hkv, D).transpose(0, 2, 1, 3)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, 1, D)
    s = jnp.einsum("bhgtd,bhsd->bhgts", qf, k.astype(jnp.float32)) / (D ** 0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    slot = jnp.arange(S)
    mask = slot[None] <= cache_lens[:, None]
    if window:
        mask &= (cache_lens[:, None] - slot[None]) < window
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgts,bhsd->bhgtd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, 1, D).astype(q.dtype)


def ssd_intra_chunk_ref(Cc, Bc, dA_cum, dt, xc):
    """Oracle for kernels.ssd_scan.ssd_intra_chunk (pairwise-einsum form,
    identical math to models/mamba2._ssd_chunk_scan's y_intra)."""
    l = Cc.shape[2]
    cb = jnp.einsum("bcis,bcjs->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    seg = (dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]).astype(
        jnp.float32)
    causal = jnp.tril(jnp.ones((l, l), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    w = cb[..., None] * L * dt[:, :, None, :, :].astype(jnp.float32)
    y = jnp.einsum("bcijh,bcjhp->bcihp", w, xc.astype(jnp.float32))
    return y.astype(xc.dtype)
