"""Pallas TPU kernel: flash-decode attention against a KV cache.

One new token per request attends to ``cache_len`` cached K/V slots. Grid
(B, Hq, nK) with the cache axis sequential; the running softmax state lives
in VMEM scratch. ``cache_len`` arrives via scalar prefetch (SMEM) so the slot
validity mask is computed on-core without materialising (B, S) masks in HBM.
Optional ``window`` masks sliding-window layers (gemma2 local) — the memory
saving for 500K decode comes from combining this with a ring cache upstream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, window, softcap,
                   block_k, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)            # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    slot = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = slot <= cache_len
    if window:
        mask &= (cache_len - slot) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ik == n_k - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k, v, cache_len, *, window: int = 0,
                     softcap: float = 0.0, block_k: int = 128,
                     interpret: bool = False):
    """q: (B, Hq, 1, D); k/v: (B, Hkv, S, D); cache_len: scalar int32 (the
    new token's slot — slots <= cache_len are attended). Returns q-shaped."""
    B, Hq, _, D = q.shape
    _, Hkv, S, _ = k.shape
    assert S % block_k == 0
    G = Hq // Hkv
    n_k = S // block_k
    grid = (B, Hq, n_k)

    kernel = functools.partial(
        _decode_kernel, scale=1.0 / (D ** 0.5), window=window,
        softcap=softcap, block_k=block_k, n_k=n_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, len_ref: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, len_ref: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D),
                               lambda b, h, ik, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(cache_len, jnp.int32).reshape(1), q, k, v)
