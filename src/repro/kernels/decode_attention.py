"""Pallas TPU kernels: flash-decode attention against a KV cache.

``decode_attention`` — dense cache. One new token per request attends to
``cache_len`` cached K/V slots. Grid (B, Hq, nK) with the cache axis
sequential; the running softmax state lives in VMEM scratch. ``cache_len``
arrives via scalar prefetch (SMEM) so the slot validity mask is computed
on-core without materialising (B, S) masks in HBM. Optional ``window`` masks
sliding-window layers (gemma2 local) — the memory saving for 500K decode
comes from combining this with a ring cache upstream.

``paged_decode_attention`` — paged cache (the serving engine's KV pool).
K/V live in a shared pool of fixed-size pages ``(n_pages, page_size, Hkv, D)``
and each request owns a *page table* of pool indices. The page table and the
per-request ``cache_lens`` are scalar-prefetched, so the BlockSpec index map
dereferences ``table[b, ip]`` on-core and the kernel DMAs exactly the pages a
request owns — no dense (B, max_seq) gather ever materialises. Per-request
cache lengths fall out for free: the validity mask compares against
``lens_ref[b]`` instead of a shared scalar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, window, softcap,
                   block_k, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)            # (1, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    slot = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = slot <= cache_len
    if window:
        mask &= (cache_len - slot) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ik == n_k - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(q, k, v, cache_len, *, window: int = 0,
                     softcap: float = 0.0, block_k: int = 128,
                     interpret: bool = False):
    """q: (B, Hq, 1, D); k/v: (B, Hkv, S, D); cache_len: scalar int32 (the
    new token's slot — slots <= cache_len are attended). Returns q-shaped."""
    B, Hq, _, D = q.shape
    _, Hkv, S, _ = k.shape
    assert S % block_k == 0
    G = Hq // Hkv
    n_k = S // block_k
    grid = (B, Hq, n_k)

    kernel = functools.partial(
        _decode_kernel, scale=1.0 / (D ** 0.5), window=window,
        softcap=softcap, block_k=block_k, n_k=n_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ik, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, len_ref: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, ik, len_ref: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D),
                               lambda b, h, ik, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(cache_len, jnp.int32).reshape(1), q, k, v)


# ----------------------------------------------------------- paged cache ----
BIG_WINDOW = 1 << 30        # "no window" sentinel (matches models.api)


def _paged_decode_kernel(tbl_ref, lens_ref, win_ref, q_ref, k_ref, v_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, scale, softcap,
                         page_size, n_pages_per_req):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = lens_ref[b]
    window = win_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)            # (1, D)
    k = k_ref[0].astype(jnp.float32)[:, 0, :]      # (page_size, D)
    v = v_ref[0].astype(jnp.float32)[:, 0, :]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    # absolute KV slot of each in-page lane: table entry ip covers slots
    # [ip * page_size, (ip+1) * page_size)
    slot = ip * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    mask = (slot <= cache_len) & ((cache_len - slot) < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(ip == n_pages_per_req - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, page_tables, cache_lens, *,
                           window: int = 0, softcap: float = 0.0,
                           interpret: bool = False):
    """Flash-decode through a page table.

    q:           (B, Hq, 1, D) — one new token per request.
    k/v_pages:   (n_pages, page_size, Hkv, D) — the shared KV pool.
    page_tables: (B, n_pages_per_req) int32 — pool index of each request
                 page; entries past the request's allocation must point at a
                 valid (e.g. null) page, they are masked by ``cache_lens``.
    cache_lens:  (B,) int32 — the new token's slot per request (slots
                 <= cache_lens[b] are attended, matching `decode_attention`).
    window:      sliding window; 0 / BIG_WINDOW = global. May be a *traced*
                 int32 scalar (it rides in SMEM via scalar prefetch), so a
                 layer scan with local/global alternation shares one compile.

    Returns (B, Hq, 1, D). The grid walks every request's full table; pages
    past ``cache_lens[b]`` are DMA'd but fully masked, so correctness never
    depends on table garbage, only the null-page convention keeps the indices
    in range.
    """
    B, Hq, _, D = q.shape
    n_pages, page_size, Hkv, _ = k_pages.shape
    _, n_pages_per_req = page_tables.shape
    G = Hq // Hkv
    grid = (B, Hq, n_pages_per_req)

    # 0 -> "global" for traced windows too (a traced zero would otherwise
    # mask every slot via (cache_len - slot) < 0)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    win = jnp.where(win > 0, win, BIG_WINDOW).astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_kernel, scale=1.0 / (D ** 0.5),
        softcap=softcap, page_size=page_size,
        n_pages_per_req=n_pages_per_req)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # page_tables, cache_lens, window
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda b, h, ip, tbl, lens, w: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, ip, tbl, lens, w: (tbl[b, ip], 0,
                                                         h // G, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, ip, tbl, lens, w: (tbl[b, ip], 0,
                                                         h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D),
                               lambda b, h, ip, tbl, lens, w: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(page_tables, jnp.int32), jnp.asarray(cache_lens, jnp.int32),
      win, q, k_pages, v_pages)
