"""Jitted public wrappers around the Pallas kernels.

These own the (B,T,H,D) <-> (B,H,T,D) layout transposes and the block
padding, so model code can call them with the layouts layers.py uses.
``interpret=True`` executes the kernel body in Python on CPU (how this repo
validates TPU kernels without TPU hardware); on a real TPU deployment the
wrappers are called with interpret=False.

Compile-cache discipline: padding happens *outside* the jitted core, so the
core only ever sees (T, S) rounded up to block multiples. Repeated group
shapes — e.g. every chunk of a capacity-padded StateStore bucket — therefore
reuse one cached executable instead of re-jitting per exact (T, S) pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.chunked_attention import (chunked_prefix_attention,
                                             ring_chunked_prefix_attention)
from repro.kernels.decode_attention import decode_attention


def _pad_to(x, axis, mult, value=0):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("softcap", "block_q", "block_k",
                                             "interpret"))
def _chunk_attention_core(q, k, v, q_pos, k_pos, q_seg, k_seg, window, *,
                          softcap, block_q, block_k, interpret):
    """Block-aligned (B,H,T,D) core. ``window`` is a dynamic int32 scalar
    (0 = disabled) so traced per-layer windows don't fragment the cache."""
    return chunked_prefix_attention(
        q, k, v, q_pos, k_pos, q_seg, k_seg, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret)


def chunk_attention(q, k, v, q_pos, k_pos, q_seg, k_seg, *, window=None,
                    softcap=0.0, block_q=128, block_k=128, interpret=True):
    """q: (B, T, Hq, D); k/v: (B, S, Hkv, D) (prefix ++ self, already
    rope-rotated); returns (B, T, Hq, D). Differentiable through the Pallas
    custom_vjp (pad/transpose cotangents route around the kernel grads).

    ``window``: None / 0 = disabled; may be a traced scalar (per-layer
    local/global alternation)."""
    B, T, Hq, D = q.shape
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, block_q)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, block_k)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, block_k)
    w = jnp.asarray(0 if window is None else window, jnp.int32)
    o = _chunk_attention_core(
        qt, kt, vt,
        _pad_to(q_pos, 1, block_q), _pad_to(k_pos, 1, block_k),
        _pad_to(q_seg, 1, block_q), _pad_to(k_seg, 1, block_k), w,
        softcap=float(softcap), block_q=block_q, block_k=block_k,
        interpret=interpret)
    return o[:, :, :T].transpose(0, 2, 1, 3)


def ring_chunk_attention(q, k, v, q_pos, k_pos, q_seg, k_seg, *, axis_name,
                         cp, window=None, softcap=0.0, block_q=128,
                         block_k=128, interpret=True, overlap=True):
    """Context-parallel chunk attention — the ``shard_map`` sibling of
    ``chunk_attention``. q: (B, T_loc, Hq, D) is this rank's query shard;
    k/v: (B, S_loc, Hkv, D) this rank's K/V ring shard (its slice of
    prefix ++ own, already rope-rotated), which circulates over ``axis_name``
    via ppermute. Not jitted here: the caller's chunk fn owns the jit (we
    are inside its shard_map region). Pad slots get seg=0 — every rank pads
    identically, so the ring stays shape-uniform. ``overlap`` double-buffers
    the ring (next hop's ppermute issued under the current hop's kernel);
    exactness is unchanged."""
    B, T, Hq, D = q.shape
    S = k.shape[1]
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, block_q)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, block_k)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, block_k)
    o = ring_chunked_prefix_attention(
        qt, kt, vt,
        _pad_to(q_pos, 1, block_q), _pad_to(k_pos, 1, block_k),
        _pad_to(q_seg, 1, block_q), _pad_to(k_seg, 1, block_k),
        axis_name=axis_name, cp=cp, window=window, softcap=float(softcap),
        block_q=block_q, block_k=block_k, interpret=interpret,
        overlap=overlap)
    return o[:, :, :T].transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block_k",
                                             "interpret"))
def cached_decode_attention(q, k, v, cache_len, *, window=0, softcap=0.0,
                            block_k=128, interpret=True):
    """q: (B, 1, Hq, D); k/v cache: (B, S, Hkv, D); cache_len: scalar."""
    B, _, Hq, D = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, block_k)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, block_k)
    o = decode_attention(qt, kt, vt, cache_len, window=window,
                         softcap=softcap, block_k=block_k,
                         interpret=interpret)
    return o.transpose(0, 2, 1, 3)
