"""Pallas TPU kernel: SSD intra-chunk quadratic pass (Mamba-2 hot spot).

Within one SSD chunk of length l the output is an attention-like product
(Mamba-2 Alg. 1):

    y[i] = sum_{j<=i} (C_i . B_j) * exp(dA_cum[i] - dA_cum[j]) * dt[j] * x[j]

Grid (B, n_chunks, H): each cell loads the chunk's C/B projections and one
head's decay/value lanes into VMEM, forms the (l, l) causal decay-weighted
score tile on the MXU, and contracts against the values. l=128..256 keeps
the tile comfortably in VMEM and MXU-aligned. The inter-chunk state
recurrence stays in the lax.scan of models/mamba2.py (it is tiny and
sequential); this kernel covers the quadratic FLOPs that dominate training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(c_ref, b_ref, da_ref, dt_ref, x_ref, o_ref, *, l):
    c = c_ref[0, 0].astype(jnp.float32)            # (l, S)
    b = b_ref[0, 0].astype(jnp.float32)            # (l, S)
    da = da_ref[0, 0, :, 0].astype(jnp.float32)    # (l,)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)    # (l,)
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)   # (l, P)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (l, l)
    seg = da[:, None] - da[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.where(row >= col, jnp.exp(seg), 0.0)
    w = cb * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (l, P)
    o_ref[0, 0, :, 0, :] = y.astype(o_ref.dtype)


def ssd_intra_chunk(Cc, Bc, dA_cum, dt, xc, *, interpret: bool = False):
    """Cc/Bc: (B, nc, l, S); dA_cum/dt: (B, nc, l, H); xc: (B, nc, l, H, P).
    Returns y_intra (B, nc, l, H, P)."""
    B, nc, l, S = Cc.shape
    H = dA_cum.shape[-1]
    P = xc.shape[-1]
    grid = (B, nc, H)

    kernel = functools.partial(_ssd_kernel, l=l)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, S), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, l, S), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, l, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, l, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, l, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nc, l, H, P), xc.dtype),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(Cc, Bc, dA_cum, dt, xc)
