"""Config dataclasses shared by every architecture.

Params are plain pytrees; a ModelConfig fully determines the param shapes and
the forward semantics (family dispatch happens in models/api.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention variants -----------------------------------------------------
    qkv_bias: bool = False
    logit_softcap: float = 0.0       # gemma2 final-logit softcap
    attn_softcap: float = 0.0        # gemma2 attention-logit softcap
    sliding_window: int = 0          # >0 -> local layers use this window
    local_global_alternate: bool = False  # gemma2: even layers local, odd global
    rope_theta: float = 1_000_000.0
    mrope: bool = False              # qwen2-vl multimodal 3D RoPE
    mrope_sections: tuple = (16, 24, 24)
    tie_embeddings: bool = False
    # MoE ---------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba2 / SSD) ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # hybrid (jamba) ----------------------------------------------------------
    attn_every: int = 0              # one attention layer per this many layers
    # encoder-decoder (whisper) ----------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # stub mel-frame count after conv frontend
    # vlm stub ----------------------------------------------------------------
    num_patches: int = 0             # stub precomputed patch embeds per sample
    # numerics ----------------------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # TP head padding: pad attention heads up to a multiple of this so the
    # head dim shards over the model axis (0 = off; the dry-run/production
    # path sets it to the TP size — pad lanes are dead weight, standard
    # Megatron practice for head counts like yi's 56 or qwen2.5-14b's 40)
    pad_heads_to: int = 0
    # attention backend selection ladder:
    #   "xla"              sdpa (short) / blockwise online-softmax (long)
    #   "pallas"           compiled flash kernel, fwd + custom_vjp bwd (TPU)
    #   "pallas_interpret" same kernels executed in interpret mode (how this
    #                      repo validates TPU kernels, incl. grads, on CPU)
    attn_backend: str = "xla"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_num_heads(self) -> int:
        if not self.pad_heads_to or not self.num_heads:
            return self.num_heads
        p = self.pad_heads_to
        return -(-self.num_heads // p) * p

    @property
    def padded_num_kv_heads(self) -> int:
        hq = self.padded_num_heads
        kv = self.num_kv_heads
        if not kv:
            return kv
        while hq % kv:
            kv += 1
        return kv

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts. Keeps every structural flag (softcap, mrope, hybrid
        interleave, ...) so the smoke test exercises the same code paths."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(1, heads // 2)) if heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2 if self.attn_every == 0 else min(self.attn_every, 8),
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=(64 if self.num_heads else 0),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=16 if self.ssm_state else self.ssm_chunk,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            num_patches=min(self.num_patches, 16),
            attn_every=min(self.attn_every, 4) if self.attn_every else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    "train",   4_096,   256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  InputShape("decode_32k",  "decode",  32_768,  128),
    "long_500k":   InputShape("long_500k",   "decode",  524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """End-to-end training/ChunkFlow settings (paper §5)."""
    chunk_size: int = 8_192
    k_chunks: int = 1                # the paper's K
    global_batch: int = 256
    micro_batch: int = 1
    learning_rate: float = 3e-5
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 10
    total_steps: int = 100
    optimizer: str = "adamw"         # adamw | adafactor
    seed: int = 0
