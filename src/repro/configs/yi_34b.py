"""Yi-34B — llama-arch GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
)
