"""Jamba-1.5-large 398B — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24_576,            # per-expert FFN width
    vocab_size=65_536,
    num_experts=16,
    experts_per_token=2,
    attn_every=8,           # 1 attention layer per 8 (7 mamba : 1 attn)
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)
