"""Whisper-small — enc-dec audio backbone, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    encoder_seq=1500,       # mel frames after the (stubbed) conv frontend
    d_model=768,
    num_heads=12,
    num_kv_heads=12,        # MHA
    d_ff=3072,
    vocab_size=51_865,
    is_encoder_decoder=True,
    rope_theta=0.0,         # whisper uses learned absolute positions
)
