"""Mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,            # attention-free
    num_kv_heads=0,
    d_ff=0,                 # mamba block subsumes the MLP
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,        # 24 SSD heads
    ssm_chunk=256,
)
