"""Architecture registry: assigned pool archs + the paper's own Qwen2.5 sizes."""
from __future__ import annotations

import dataclasses

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import (
    kimi_k2_1t_a32b,
    whisper_small,
    gemma2_2b,
    qwen2_vl_2b,
    mamba2_130m,
    qwen2_5_14b,
    granite_3_8b,
    granite_moe_1b_a400m,
    jamba_1_5_large_398b,
    yi_34b,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        kimi_k2_1t_a32b.CONFIG,
        whisper_small.CONFIG,
        gemma2_2b.CONFIG,
        qwen2_vl_2b.CONFIG,
        mamba2_130m.CONFIG,
        qwen2_5_14b.CONFIG,
        granite_3_8b.CONFIG,
        granite_moe_1b_a400m.CONFIG,
        jamba_1_5_large_398b.CONFIG,
        yi_34b.CONFIG,
    ]
}

# The paper evaluates Qwen2.5-{7,14,32,72}B (Table 3); 14B is in the assigned
# pool already, the rest are provided for the paper-faithful experiments.
_QWEN = qwen2_5_14b.CONFIG
PAPER_ARCHS: dict[str, ModelConfig] = {
    "qwen2.5-7b": dataclasses.replace(
        _QWEN, name="qwen2.5-7b", num_layers=28, d_model=3584, num_heads=28,
        num_kv_heads=4, d_ff=18_944),
    "qwen2.5-14b": _QWEN,
    "qwen2.5-32b": dataclasses.replace(
        _QWEN, name="qwen2.5-32b", num_layers=64, d_model=5120, num_heads=40,
        num_kv_heads=8, d_ff=27_648),
    "qwen2.5-72b": dataclasses.replace(
        _QWEN, name="qwen2.5-72b", num_layers=80, d_model=8192, num_heads=64,
        num_kv_heads=8, d_ff=29_568),
}


def get_arch(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in PAPER_ARCHS:
        return PAPER_ARCHS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS) + sorted(PAPER_ARCHS)}")


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


# (arch, shape) pairs intentionally skipped, with the DESIGN.md §4 reason.
SKIPPED_PAIRS: dict[tuple[str, str], str] = {
    ("kimi-k2-1t-a32b", "long_500k"): "pure full attention; no sub-quadratic variant",
    ("qwen2.5-14b", "long_500k"): "pure full attention; no sub-quadratic variant",
    ("granite-3-8b", "long_500k"): "pure full attention; no sub-quadratic variant",
    ("granite-moe-1b-a400m", "long_500k"): "pure full attention; no sub-quadratic variant",
    ("yi-34b", "long_500k"): "pure full attention; no sub-quadratic variant",
    ("qwen2-vl-2b", "long_500k"): "pure full attention; no sub-quadratic variant",
    ("whisper-small", "long_500k"): "decoder context architecturally 448; conv frontend",
}


def runnable_pairs() -> list[tuple[str, str]]:
    out = []
    for arch in ARCHS:
        for shape in INPUT_SHAPES:
            if (arch, shape) not in SKIPPED_PAIRS:
                out.append((arch, shape))
    return out
