"""Granite-MoE 1B-a400m — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,               # per-expert FFN width
    vocab_size=49_155,
    num_experts=32,
    experts_per_token=8,
)
