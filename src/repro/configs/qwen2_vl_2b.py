"""Qwen2-VL 2B backbone — M-RoPE, dynamic resolution (vision tower stubbed)
[arXiv:2409.12191]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    num_patches=256,        # stub: precomputed SigLIP/ViT patch embeds per image
)
