"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,          # 7168 / 64
    d_ff=2048,             # per-expert FFN width
    vocab_size=163_840,
    num_experts=384,
    experts_per_token=8,
)
