"""AdamW + cosine LR schedule + global-norm clipping, pure JAX pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine_schedule(step, *, base_lr, warmup_steps, total_steps,
                    min_ratio=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5
                     * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(params, grads, opt_state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, grad_clip=1.0):
    if grad_clip:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        _, gnorm = clip_by_global_norm(grads, 1e9)
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)

    def upd_slice(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    def upd(p, g, m, v):
        # layer-stacked tensors: update slice-by-slice so the fp32
        # temporaries stay one layer wide (memory_analysis-visible on CPU)
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: upd_slice(*a), (p, g, m, v))
        return upd_slice(p, g, m, v)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
