"""Adafactor (factored second moment) — the optimizer this repo uses for the
>=398B archs where AdamW's fp32 m/v cannot fit a v5e pod (DESIGN.md §6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"slots": jax.tree.map(init, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, opt_state, *, lr, decay=0.8,
                     eps=1e-30, clip_threshold=1.0):
    step = opt_state["step"] + 1
    beta = 1.0 - step.astype(jnp.float32) ** -decay

    def upd_slice(p, g, slot):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            vr = beta * slot["vr"] + (1 - beta) * g2.mean(axis=-1)
            vc = beta * slot["vc"] + (1 - beta) * g2.mean(axis=-2)
            denom = (vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                     )[..., None] * vc[..., None, :]
            u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta * slot["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
            new_slot = {"v": v}
        rms = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_slot

    def upd(p, g, slot):
        # layer-stacked tensors: per-layer slices keep fp32 temporaries small
        # (update-RMS clipping becomes per-layer — noted in DESIGN.md)
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda a: upd_slice(a[0], a[1], a[2]),
                               (p, g, slot))
        return upd_slice(p, g, slot)

    leaves, tdef = jax.tree.flatten(params)
    gl = jax.tree.leaves(grads)
    sl = tdef.flatten_up_to(opt_state["slots"])
    out = [upd(p, g, s) for p, g, s in zip(leaves, gl, sl)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            {"slots": jax.tree.unflatten(tdef, [o[1] for o in out]),
             "step": step})
