"""Context-parallel ("seq" axis) chunk execution — ring flash attention.

ChunkFlow bounds peak activation memory by ChunkSize, but a single chunk's
attention still runs on one device, so ChunkSize (and with it long-tail
throughput) is capped by one accelerator's HBM. This module removes that cap
the FlexSP / FPDT way: a chunk's tokens are sharded over a third mesh axis
``"seq"`` and its K/V circulates around the CP group as a ``ppermute`` ring
(ring flash attention — per-hop partials merged with the online-softmax LSE
residual, the existing Pallas ``custom_vjp`` backward reused per hop; see
``kernels.chunked_attention.ring_chunked_prefix_attention``).

Sharding contract (the AD-safe one — every shard_map input/output that
carries gradient is *sharded*, only params are replicated, matching the
pipeline executor's proven pattern):

  * Q / activations / logits: token dim sharded over "seq". Pointwise layer
    math needs no communication; the loss sum happens outside shard_map in
    GSPMD-land on the reassembled logits.
  * StateStore prefix K/V (and its pos/seg metadata): capacity dim sharded
    over "seq" — rank i holds the contiguous [i*cap/cp, (i+1)*cap/cp) slice,
    which IS its ring shard (prefix slice ++ own-token K/V). Peak per-device
    K/V therefore scales 1/cp.
  * Own-chunk K/V leaves shard_map token-sharded; `ss.write_own` then updates
    the seq-sharded prefix buffer in GSPMD-land, so the Algorithm-2 executor
    (run_group) is reused unchanged — only the chunk fn differs.

The dp_balance planner treats a CP group as ONE logical (faster) rank:
eligible units' token-work is divided by cp and ineligible (short) units
keep full cost and run seq-replicated — `cp_threshold` keeps sub-ring-latency
chunks off the ring (`dp_balance.cp_eligible`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import dp_balance
from repro.distributed import sharding
from repro.distributed.compat import shard_map
from repro.models import api
from repro.models import layers as L

AXIS = "seq"

# Trace-time log of the jitted CP chunk fn — one entry per Python retrace
# (== per fresh XLA compile), recording (cfg, cp, prefix_capacity, rows, C).
CP_TRACE_EVENTS: list = []


def reset_cp_trace_log():
    CP_TRACE_EVENTS.clear()
    _cp_chunk_fn.cache_clear()


@functools.lru_cache(maxsize=None)
def _cp_chunk_fn(cfg: ModelConfig, blockwise_threshold: int, mesh, cp: int,
                 ring_overlap: bool = True):
    """Jitted Algorithm-2 chunk fn with the transformer trunk under a
    shard_map over ("data", "seq"): (params, prefix, batch) -> (loss, own).
    Drop-in replacement for `chunked_step._jitted_chunk_fn` on ring waves.
    Mirrors `api._decoder_forward` exactly (per-layer windows, prefix
    pos/seg metadata) so CP losses and grads match single-device to <=1e-5.
    """
    win_np = api._layer_windows(cfg)

    def trunk(layer_params, windows, x, pos, seg, pk, pv, p_pos, p_seg):
        # x: (r, C/cp, D) this rank's token shard; pk/pv: (L, r, cap/cp,
        # Hkv, hd) this rank's contiguous StateStore ring shard.
        def layer_fn(x, xs):
            lp, window, k_ring, v_ring = xs
            prefix = {"k": k_ring, "v": v_ring, "pos": p_pos, "seg": p_seg}
            h, new_kv = L.attention_layer(
                lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                positions=pos, segment_ids=seg, prefix=prefix, window=window,
                blockwise_threshold=blockwise_threshold, cp_axis=AXIS, cp=cp,
                ring_overlap=ring_overlap)
            x = x + h
            h2 = L.swiglu_mlp(lp["mlp"], L.rms_norm(x, lp["ln2"],
                                                    cfg.norm_eps))
            return x + h2, new_kv

        y, new_kv = jax.lax.scan(layer_fn, x,
                                 (layer_params, windows, pk, pv))
        return y, new_kv["k"], new_kv["v"]

    def f(params, prefix, batch):
        from repro.core.chunked_step import token_nll_sum
        R, C = batch["tokens"].shape
        cap = prefix["k"].shape[2]
        CP_TRACE_EVENTS.append((cfg.name, cp, cap, R, C))
        x = params["embed"][batch["tokens"]]
        windows = jnp.asarray(win_np)
        outs, ok, ov = shard_map(
            trunk, mesh=mesh,
            in_specs=(P(), P(),
                      P("data", AXIS),          # x (R, C, D)
                      P("data", AXIS),          # positions
                      P("data", AXIS),          # segment_ids
                      P(None, "data", AXIS),    # prefix k (L, R, cap, H, hd)
                      P(None, "data", AXIS),    # prefix v
                      P("data", AXIS),          # prefix_pos (R, cap)
                      P("data", AXIS)),         # prefix_seg
            out_specs=(P("data", AXIS), P(None, "data", AXIS),
                       P(None, "data", AXIS)),
            check_vma=False,
        )(params["layers"], windows, x, batch["positions"],
          batch["segment_ids"], prefix["k"], prefix["v"],
          batch["prefix_pos"], batch["prefix_seg"])
        xg = L.rms_norm(outs, params["ln_f"], cfg.norm_eps)
        logits = api._unembed(cfg, params, xg)
        loss = token_nll_sum(logits, batch["labels"], batch["loss_mask"])
        own = {"k": ok, "v": ov}
        return loss, own

    return jax.jit(f)


def ring_wave(wave) -> bool:
    """A lockstep wave rides the ring iff any of its units is ring-eligible
    (eligibility is monotone in chunk count, every unit is padded to the
    wave's longest anyway, and C is uniform — so this equals 'the wave's
    largest unit is eligible')."""
    return any(u is not None and u.ring for u in wave)


def run_batch_cp(cfg: ModelConfig, params, batch, plan=None, mesh=None, *,
                 k: int = None, blockwise_threshold: int = None,
                 plan_policy: str = None, cp_threshold: int = None):
    """One training micro-iteration on a (data x seq) context-parallel mesh,
    driven by an ExecutionPlan: ``run_batch_cp(cfg, params,
    (groups, standalone), plan)``. (The legacy ``(cfg, params, groups,
    standalone, mesh, k=..., cp_threshold=...)`` signature still works
    under DeprecationWarning — `chunked_step.coerce_plan`.)

    Same wave orchestration as the DP executor (`chunked_step
    .run_planned_waves`); the plan decides per wave: cp > 1 waves swap the
    chunk fn for the shard_map ring trunk (dp_size rows, tokens sharded
    over "seq"), cp == 1 waves run the plain GSPMD chunk fn — under a
    solved plan they are WIDENED to dp_size * seq_size rows over the
    combined ("data", "seq") axes, so the would-be ring ranks each execute
    their own unit and no ring hops are paid. Numerically equivalent to the
    single-device `run_batch` to <=1e-5 (tests/test_context_parallel.py,
    tests/test_planner.py) under any plan — gradients sum linearly and
    dummy rows contribute zero, so the plan only moves performance.
    """
    if cfg.family != "dense":
        raise NotImplementedError(
            f"run_batch_cp: config {cfg.name!r} requests family "
            f"{cfg.family!r}, but the context-parallel executor supports "
            "only {'dense'} (the ring attention kernel assumes a uniform "
            "stacked-decoder KV layout). Run this config through "
            "run_batch (single-device or data-parallel) instead, or lower "
            "cp to 1 in the ExecutionPlan.")
    from repro.core import chunked_step as cs

    groups, standalone, plan = cs.coerce_plan(
        batch, plan, mesh, k=k, blockwise_threshold=blockwise_threshold,
        plan_policy=plan_policy, cp_threshold=cp_threshold,
        where="run_batch_cp")
    mesh = plan.mesh
    S = sharding.seq_size(mesh)
    scale = cs._batch_loss_scale(groups, standalone)

    def eff_cp(wave, slots):
        """Runtime geometry guard: the ring shards tokens, so C must divide
        by cp (hand-built plans may violate it — fall back to packing)."""
        cp = wave.cp
        if cp > 1 and cp != S:
            raise ValueError(f"wave cp={cp} != mesh seq size {S}: ring "
                             "waves run at exactly the \"seq\" axis width")
        return cp if cp > 1 and slots[0]["tokens"].shape[1] % cp == 0 else 1

    def chunk_fn_for_wave(wave, slots):
        cp = eff_cp(wave, slots)
        if cp > 1:
            return _cp_chunk_fn(cfg, plan.blockwise_threshold, mesh, cp,
                                plan.ring_overlap)
        return None

    def wave_done(wave, slots, stats, n_fwd, n_bwd):
        cp = eff_cp(wave, slots)
        stats.wave_cps[-1] = cp
        if cp > 1:
            stats.ring_steps += dp_balance.ring_hops(n_fwd, n_bwd, cp,
                                                     cfg.num_layers)
            if plan.ring_overlap:
                stats.overlapped_hops += dp_balance.overlapped_ring_hops(
                    n_fwd, n_bwd, cp, cfg.num_layers)

    return cs.run_planned_waves(
        cfg, params, plan, scale=scale,
        chunk_fn_for_wave=chunk_fn_for_wave, wave_done=wave_done)
