"""SPMD pipeline-parallel executor for chunk streams (paper §4.3, adapted).

TPU/JAX adaptation (DESIGN.md §2): Megatron's 1F1B is an imperative per-rank
schedule; in JAX the idiomatic equivalent is an SPMD rotation pipeline —
``shard_map`` over a ``pipe`` mesh axis, stage weights sharded on their
leading dim, activations handed to the next stage with
``lax.collective_permute`` each tick, ``M + S - 1`` ticks total. Backward is
obtained by differentiating through the rotation (collective_permute
transposes to the reverse permutation), which XLA schedules 1F1B-style per
stage. The *state-aware* part is preserved exactly: each stage keeps a
resident K/V buffer for the dependent group being streamed, so chunk ``j``
attends to the K/V of chunks ``< j`` computed on that same stage — the
paper's StateStore, pipelined.

The schedule-level analysis (bubble ratios, recompute placement, K trade-off)
lives in core/schedule_sim.py; this module is the executable counterpart and
is validated for numerical equivalence in tests/test_pipeline_exec.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.compat import pcast_varying, shard_map
from repro.models import layers as L


def split_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...)."""
    def r(a):
        Lc = a.shape[0]
        assert Lc % n_stages == 0, (Lc, n_stages)
        return a.reshape(n_stages, Lc // n_stages, *a.shape[1:])
    return jax.tree.map(r, layer_params)


def _stage_apply(cfg: ModelConfig, stage_layers, x, pos, seg,
                 kbuf, vbuf, prefix_valid):
    """Run this stage's layer slab over one chunk.

    kbuf/vbuf: (Lp, B, maxP, Hkv, hd) resident K/V of earlier chunks;
    prefix_valid: (maxP,) bool — which prefix slots are live for this chunk.
    Returns (y, new_k (Lp,B,T,Hkv,hd), new_v).
    """
    B, T, _ = x.shape
    maxP = kbuf.shape[2]
    p_pos = jnp.broadcast_to(jnp.arange(maxP, dtype=jnp.int32), (B, maxP))
    p_seg = jnp.broadcast_to(prefix_valid.astype(jnp.int32), (B, maxP))

    def layer_fn(x, xs):
        lp, pk, pv = xs
        prefix = {"k": pk, "v": pv, "pos": p_pos, "seg": p_seg}
        h, new_kv = L.attention_layer(
            lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            positions=pos, segment_ids=seg, prefix=prefix,
            blockwise_threshold=1 << 30)
        x = x + h
        h2 = L.swiglu_mlp(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps))
        return x + h2, new_kv

    y, new_kv = jax.lax.scan(layer_fn, x, (stage_layers, kbuf, vbuf))
    return y, new_kv["k"], new_kv["v"]


def pipelined_chunk_forward(cfg: ModelConfig, stage_layers, x_mbs, pos_mbs,
                            seg_mbs, dep_flags, chunk_size: int,
                            axis: str = "pipe"):
    """Inside shard_map: run M chunk microbatches through S stages.

    x_mbs: (M, B, T, D) embedded chunks (replicated); dep_flags: (M,) int32 —
    1 if the chunk belongs to THE dependent group of this stream (its K/V is
    stored and later chunks of the group attend to it). Returns (M, B, T, D)
    outputs (valid on every device after psum).
    """
    s = jax.lax.axis_index(axis)
    S = jax.lax.psum(1, axis)
    M, B, T, D = x_mbs.shape
    maxP = chunk_size * M
    Lp = jax.tree.leaves(stage_layers)[0].shape[0]
    hd = cfg.resolved_head_dim

    def varying(x):
        return pcast_varying(x, (axis,))

    kbuf0 = varying(jnp.zeros((Lp, B, maxP, cfg.num_kv_heads, hd), x_mbs.dtype))
    vbuf0 = jnp.zeros_like(kbuf0)
    outs0 = varying(jnp.zeros_like(x_mbs))
    state0 = varying(jnp.zeros((B, T, D), x_mbs.dtype))
    # how many dependent chunks precede each mb in the stream
    dep_prefix_chunks = jnp.cumsum(dep_flags) - dep_flags      # (M,)

    def tick(carry, t):
        state, kbuf, vbuf, outs = carry
        j = jnp.clip(t - s, 0, M - 1)
        valid = (t - s >= 0) & (t - s < M)

        x_in = jnp.where(s == 0, x_mbs[j], state)
        pos, seg = pos_mbs[j], seg_mbs[j]
        is_dep = dep_flags[j] > 0
        plen = jnp.where(is_dep, dep_prefix_chunks[j] * chunk_size, 0)
        prefix_valid = jnp.arange(maxP) < plen

        y, nk, nv = _stage_apply(cfg, stage_layers, x_in, pos, seg,
                                 kbuf, vbuf, prefix_valid)

        # store this chunk's K/V into the resident group buffer
        write = (valid & is_dep).astype(kbuf.dtype)
        off = dep_prefix_chunks[j] * chunk_size
        upd = jax.lax.dynamic_slice(kbuf, (0, 0, off, 0, 0),
                                    (Lp, B, T, cfg.num_kv_heads, hd))
        kbuf = jax.lax.dynamic_update_slice(
            kbuf, upd * (1 - write) + nk * write, (0, 0, off, 0, 0))
        upd = jax.lax.dynamic_slice(vbuf, (0, 0, off, 0, 0),
                                    (Lp, B, T, cfg.num_kv_heads, hd))
        vbuf = jax.lax.dynamic_update_slice(
            vbuf, upd * (1 - write) + nv * write, (0, 0, off, 0, 0))

        # last stage records its output for mb j
        is_last = (s == S - 1)
        rec = (valid & is_last).astype(outs.dtype)
        cur = jax.lax.dynamic_slice(outs, (j, 0, 0, 0), (1, B, T, D))
        outs = jax.lax.dynamic_update_slice(
            outs, cur * (1 - rec) + y[None] * rec, (j, 0, 0, 0))

        # rotate activations to the next stage
        perm = [(i, (i + 1) % S) for i in range(S)]
        state = jax.lax.ppermute(y, axis, perm)
        return (state, kbuf, vbuf, outs), None

    (_, _, _, outs), _ = jax.lax.scan(
        tick, (state0, kbuf0, vbuf0, outs0),
        jnp.arange(M + S - 1))
    return jax.lax.psum(outs * (s == S - 1), axis)


def make_pipeline_step(cfg: ModelConfig, mesh, n_stages: int,
                       chunk_size: int, axis: str = "pipe"):
    """Build a jitted pipeline-parallel loss/grad step.

    params: api.init_params output for a dense cfg with layers divisible by
    n_stages. Batch: dict of (M, B, T) arrays + dep_flags (M,).
    """
    from repro.core.chunked_step import token_nll_sum

    def body(sl, x, pos, seg, dep):
        return pipelined_chunk_forward(cfg, sl, x, pos, seg, dep,
                                       chunk_size, axis)

    def loss_fn(params, batch):
        stage_layers = split_stages(params["layers"], n_stages)
        x_mbs = params["embed"][batch["tokens"]]
        outs = shard_map(
            body, mesh=mesh,
            in_specs=(P(axis), P(), P(), P(), P()),
            out_specs=P(),
            check_vma=False,
        )(stage_layers, x_mbs, batch["positions"], batch["segment_ids"],
          batch["dep_flags"])
        x = L.rms_norm(outs, params["ln_f"], cfg.norm_eps)
        logits = (x @ params["unembed"]).astype(jnp.float32)
        M = logits.shape[0]
        loss = token_nll_sum(
            logits.reshape(M * logits.shape[1], *logits.shape[2:]),
            batch["labels"].reshape(-1, batch["labels"].shape[-1]),
            batch["loss_mask"].reshape(-1, batch["loss_mask"].shape[-1]))
        return loss * batch["loss_scale"]

    return jax.jit(jax.value_and_grad(loss_fn))
