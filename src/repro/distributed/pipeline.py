"""SPMD pipeline-parallel executors for chunk streams (paper §4.3, adapted).

TPU/JAX adaptation (DESIGN.md §2): Megatron's 1F1B is an imperative per-rank
schedule; in JAX the idiomatic equivalent is an SPMD rotation pipeline —
``shard_map`` over a ``pipe`` mesh axis, stage weights sharded on their
leading (layer) dim, activations handed to the next stage with
``lax.ppermute`` each tick, ``W + S - 1`` ticks per scan of W microbatches.

Two executors live here:

  * ``make_pipeline_step`` — the original full-residency reference: one
    differentiable scan over the whole stream, every chunk's K/V and every
    chunk's differentiation residuals held live. Simple, and the numerical
    oracle for the real path below (tests/test_pipeline_exec.py).

  * ``run_batch_pipelined`` — the trainable 2D (``data`` x ``pipe``) path:
    Algorithm 2 at pipeline scale. The dp_balance planner assigns chunk
    groups to DP ranks and the work runs as lockstep waves exactly like
    ``chunked_step._run_batch_dp``; within a wave the chunk stream is split
    into windows of at most K chunks and each window is one rotation scan.
    Only the LAST window's forward runs under ``jax.vjp`` — at most K chunk
    microbatches' residuals are ever live — and every earlier window is
    re-forwarded (F2) immediately before its backward, so the executor's
    schedule is exactly ``schedule_sim.simulate_rotation``'s closed form
    (tests/test_pipeline2d.py pins the accounting to be identical).

State layout: per stage, the chunks' K/V lives in ONE capacity-padded
StateStore buffer (PR 2 layout — ``prefix_capacity`` bucketing, chunk i's
own K/V written at slot offset ``i*C``, unused slots keep seg=0 and are
exactly masked). The buffer is threaded through the window scans as a
shard_map carry, sharded layer-dim over ``pipe`` and batch-dim over
``data``. The K knob does NOT shrink this buffer — chunk i's recompute reads
the K/V of every chunk j < i, so the group's K/V must stay resident (same as
the single-device executor, where ``prefixes`` holds all K/V and only the
vjp residuals are bounded by K). What K bounds is the dominant memory term:
live differentiation residuals (per-layer activations), measured per window
via the vjp pytree. Gradients flow back through the K/V buffer chain —
window w's vjp consumes the accumulated K/V cotangent and returns the
cotangent w.r.t. its input buffer, which routes each slot's gradient to the
producing window automatically (the pipelined ``split_prefix_cot``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import dp_balance
from repro.core import statestore as ss
from repro.distributed import sharding
from repro.distributed.compat import pcast_varying, shard_map
from repro.models import api
from repro.models import layers as L


def split_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...)."""
    def r(a):
        Lc = a.shape[0]
        assert Lc % n_stages == 0, (Lc, n_stages)
        return a.reshape(n_stages, Lc // n_stages, *a.shape[1:])
    return jax.tree.map(r, layer_params)


def _stage_apply(cfg: ModelConfig, stage_layers, windows, x, pos, seg,
                 kbuf, vbuf, p_pos, p_seg, blockwise_threshold: int,
                 cp: int = 1, cp_axis: str = "seq",
                 ring_overlap: bool = True):
    """Run this stage's layer slab over one chunk.

    kbuf/vbuf: (Lp, B, cap, Hkv, hd) resident K/V of earlier chunks;
    p_pos/p_seg: (B, cap) int32 prefix metadata (seg=0 slots are masked).
    windows: (Lp,) per-layer sliding windows (api._layer_windows slab).
    Mirrors api._decoder_forward's layer body exactly so the pipeline is
    numerically identical to the single-device chunk fn.
    Returns (y, new_k (Lp,B,T,Hkv,hd), new_v).

    With ``cp > 1`` all token dims (x/pos/seg and the kbuf/vbuf capacity
    dim) are this rank's "seq" shard and attention runs as a ppermute ring
    over ``cp_axis``; the returned new K/V is the local token shard.
    """
    def layer_fn(x, xs):
        lp, window, pk, pv = xs
        prefix = {"k": pk, "v": pv, "pos": p_pos, "seg": p_seg}
        h, new_kv = L.attention_layer(
            lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            positions=pos, segment_ids=seg, prefix=prefix, window=window,
            blockwise_threshold=blockwise_threshold,
            cp_axis=(cp_axis if cp > 1 else None), cp=cp,
            ring_overlap=ring_overlap)
        x = x + h
        h2 = L.swiglu_mlp(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps))
        return x + h2, new_kv

    y, new_kv = jax.lax.scan(layer_fn, x, (stage_layers, windows, kbuf, vbuf))
    return y, new_kv["k"], new_kv["v"]


# =========================================================================
# Full-residency reference executor (kept as the numerical oracle)
# =========================================================================
def pipelined_chunk_forward(cfg: ModelConfig, stage_layers, x_mbs, pos_mbs,
                            seg_mbs, dep_flags, chunk_size: int,
                            axis: str = "pipe"):
    """Inside shard_map: run M chunk microbatches through S stages.

    x_mbs: (M, B, T, D) embedded chunks (replicated); dep_flags: (M,) int32 —
    1 if the chunk belongs to THE dependent group of this stream (its K/V is
    stored and later chunks of the group attend to it). Returns (M, B, T, D)
    outputs (valid on every device after psum).
    """
    s = jax.lax.axis_index(axis)
    S = jax.lax.psum(1, axis)
    M, B, T, D = x_mbs.shape
    maxP = chunk_size * M
    Lp = jax.tree.leaves(stage_layers)[0].shape[0]
    hd = cfg.resolved_head_dim
    windows = jnp.full((Lp,), 1 << 30, jnp.int32)

    def varying(x):
        return pcast_varying(x, (axis,))

    kbuf0 = varying(jnp.zeros((Lp, B, maxP, cfg.num_kv_heads, hd), x_mbs.dtype))
    vbuf0 = jnp.zeros_like(kbuf0)
    outs0 = varying(jnp.zeros_like(x_mbs))
    state0 = varying(jnp.zeros((B, T, D), x_mbs.dtype))
    # how many dependent chunks precede each mb in the stream
    dep_prefix_chunks = jnp.cumsum(dep_flags) - dep_flags      # (M,)

    def tick(carry, t):
        state, kbuf, vbuf, outs = carry
        j = jnp.clip(t - s, 0, M - 1)
        valid = (t - s >= 0) & (t - s < M)

        x_in = jnp.where(s == 0, x_mbs[j], state)
        pos, seg = pos_mbs[j], seg_mbs[j]
        is_dep = dep_flags[j] > 0
        plen = jnp.where(is_dep, dep_prefix_chunks[j] * chunk_size, 0)
        prefix_valid = jnp.arange(maxP) < plen
        p_pos = jnp.broadcast_to(jnp.arange(maxP, dtype=jnp.int32), (B, maxP))
        p_seg = jnp.broadcast_to(prefix_valid.astype(jnp.int32), (B, maxP))

        y, nk, nv = _stage_apply(cfg, stage_layers, windows, x_in, pos, seg,
                                 kbuf, vbuf, p_pos, p_seg, 1 << 30)

        # store this chunk's K/V into the resident group buffer
        write = (valid & is_dep).astype(kbuf.dtype)
        off = dep_prefix_chunks[j] * chunk_size
        upd = jax.lax.dynamic_slice(kbuf, (0, 0, off, 0, 0),
                                    (Lp, B, T, cfg.num_kv_heads, hd))
        kbuf = jax.lax.dynamic_update_slice(
            kbuf, upd * (1 - write) + nk * write, (0, 0, off, 0, 0))
        upd = jax.lax.dynamic_slice(vbuf, (0, 0, off, 0, 0),
                                    (Lp, B, T, cfg.num_kv_heads, hd))
        vbuf = jax.lax.dynamic_update_slice(
            vbuf, upd * (1 - write) + nv * write, (0, 0, off, 0, 0))

        # last stage records its output for mb j
        is_last = (s == S - 1)
        rec = (valid & is_last).astype(outs.dtype)
        cur = jax.lax.dynamic_slice(outs, (j, 0, 0, 0), (1, B, T, D))
        outs = jax.lax.dynamic_update_slice(
            outs, cur * (1 - rec) + y[None] * rec, (j, 0, 0, 0))

        # rotate activations to the next stage
        perm = [(i, (i + 1) % S) for i in range(S)]
        state = jax.lax.ppermute(y, axis, perm)
        return (state, kbuf, vbuf, outs), None

    (_, _, _, outs), _ = jax.lax.scan(
        tick, (state0, kbuf0, vbuf0, outs0),
        jnp.arange(M + S - 1))
    return jax.lax.psum(outs * (s == S - 1), axis)


def make_pipeline_step(cfg: ModelConfig, mesh, n_stages: int,
                       chunk_size: int, axis: str = "pipe"):
    """Build a jitted pipeline-parallel loss/grad step (full residency).

    params: api.init_params output for a dense cfg with layers divisible by
    n_stages. Batch: dict of (M, B, T) arrays + dep_flags (M,).
    """
    from repro.core.chunked_step import token_nll_sum

    def body(sl, x, pos, seg, dep):
        return pipelined_chunk_forward(cfg, sl, x, pos, seg, dep,
                                       chunk_size, axis)

    def loss_fn(params, batch):
        stage_layers = split_stages(params["layers"], n_stages)
        x_mbs = params["embed"][batch["tokens"]]
        outs = shard_map(
            body, mesh=mesh,
            in_specs=(P(axis), P(), P(), P(), P()),
            out_specs=P(),
            check_vma=False,
        )(stage_layers, x_mbs, batch["positions"], batch["segment_ids"],
          batch["dep_flags"])
        x = L.rms_norm(outs, params["ln_f"], cfg.norm_eps)
        logits = (x @ params["unembed"]).astype(jnp.float32)
        M = logits.shape[0]
        loss = token_nll_sum(
            logits.reshape(M * logits.shape[1], *logits.shape[2:]),
            batch["labels"].reshape(-1, batch["labels"].shape[-1]),
            batch["loss_mask"].reshape(-1, batch["loss_mask"].shape[-1]))
        return loss * batch["loss_scale"]

    return jax.jit(jax.value_and_grad(loss_fn))


# =========================================================================
# 2D (data x pipe) K-retention executor — Algorithm 2 at pipeline scale
# =========================================================================

# Trace-time log of the jitted window fn — one entry per Python retrace
# (== per fresh XLA compile), recording (cfg, window, capacity, rows, C).
# The pipeline benchmark's compile-count regression metric reads this.
PIPE_TRACE_EVENTS: list = []


def reset_pipe_trace_log():
    PIPE_TRACE_EVENTS.clear()
    _window_step_fn.cache_clear()


@dataclasses.dataclass
class PipelineStats:
    """Mirrors SchedulerStats fields (train.py reads them) + the rotation
    schedule accounting that tests pin against simulate_rotation."""
    forward_calls: int = 0
    recompute_calls: int = 0
    backward_calls: int = 0
    max_live_residuals: int = 0        # live residual chunk-states (<= K)
    ring_steps: int = 0                # context-parallel ppermute hops
    overlapped_hops: int = 0           # hops issued under a kernel (overlap)
    wave_cps: list = dataclasses.field(default_factory=list)  # effective cp
    # tick accounting, in simulate_rotation units (F tick = 1, B tick = 2)
    makespan_units: float = 0.0
    useful_units: float = 0.0          # F + B work summed across stages
    recompute_units: float = 0.0       # F2 work summed across stages
    n_stages: int = 0
    # state accounting
    wave_sizes: list = dataclasses.field(default_factory=list)
    kv_capacity_slots: list = dataclasses.field(default_factory=list)
    kv_store_bytes: int = 0            # peak StateStore K/V bytes (all stages)
    peak_residual_bytes: int = 0       # measured from the live vjp pytree
    scans: list = dataclasses.field(default_factory=list)

    @property
    def bubble_ratio(self) -> float:
        total = self.n_stages * self.makespan_units
        return (total - self.useful_units) / total if total else 0.0


def _windows_slab(cfg: ModelConfig, n_stages: int):
    return np.asarray(api._layer_windows(cfg)).reshape(
        n_stages, cfg.num_layers // n_stages)


@functools.lru_cache(maxsize=None)
def _window_step_fn(cfg: ModelConfig, mesh, n_stages: int,
                    blockwise_threshold: int, axis: str, cp: int = 1,
                    wide: bool = False, ring_overlap: bool = True):
    """Jitted loss/state fn for ONE rotation window: (params, kv, batch) ->
    (loss, kv_out). Compiles once per (window, capacity, rows) shape.

    cp > 1 adds context parallelism inside the same shard_map: token dims
    (x/pos/seg and the K/V capacity dim) shard over "seq", attention runs
    the ppermute ring per tick, and each chunk's own K/V is all-gathered
    over "seq" then written by the rank whose StateStore shard owns its
    slot (the write region [off, off+C) lies inside one shard — waves where
    it wouldn't, cap/cp % C != 0, fall back to cp=1 seq-replication).

    ``wide`` is the planner's packed cp=1 mode on a mesh that HAS a "seq"
    axis: the wave was widened to dp * seq rows, so the row dim shards over
    the combined ("data", "seq") axes — the would-be ring ranks each run
    their own unit, tokens stay whole, no ring hops. (cp > 1 and wide are
    mutually exclusive.)
    """
    win_np = _windows_slab(cfg, n_stages)

    def body(stage_layers, windows, kv, x_mbs, pos_mbs, seg_mbs,
             ppos_mbs, pseg_mbs, offsets, write_flags):
        s = jax.lax.axis_index(axis)
        S = n_stages
        stage_layers = jax.tree.map(lambda a: a[0], stage_layers)
        windows = windows[0]
        kbuf, vbuf = kv["k"], kv["v"]          # (Lp, r, cap, Hkv, hd) local
        W, r, C, D = x_mbs.shape               # C, cap: "seq"-local lengths
        Lp, _, cap, Hkv, hd = kbuf.shape
        Cg = C * cp                            # global chunk length
        iq = jax.lax.axis_index("seq") if cp > 1 else 0

        def varying(x):
            return pcast_varying(x, (axis,))

        state0 = varying(jnp.zeros((r, C, D), x_mbs.dtype))
        outs0 = varying(jnp.zeros_like(x_mbs))
        kbuf = varying(kbuf)
        vbuf = varying(vbuf)

        def tick(carry, t):
            state, kbuf, vbuf, outs = carry
            j = jnp.clip(t - s, 0, W - 1)
            valid = (t - s >= 0) & (t - s < W)

            x_in = jnp.where(s == 0, x_mbs[j], state)
            y, nk, nv = _stage_apply(
                cfg, stage_layers, windows, x_in, pos_mbs[j], seg_mbs[j],
                kbuf, vbuf, ppos_mbs[j], pseg_mbs[j], blockwise_threshold,
                cp=cp, ring_overlap=ring_overlap)

            if cap >= Cg:      # store this chunk's K/V at its slot offset
                write = (valid & (write_flags[j] > 0)).astype(kbuf.dtype)
                off = offsets[j]               # global slot offset (g * Cg)
                if cp > 1:
                    # gather the token-sharded own K/V; only the rank whose
                    # contiguous StateStore shard owns [off, off+Cg) writes
                    nk = jax.lax.all_gather(nk, "seq", axis=2, tiled=True)
                    nv = jax.lax.all_gather(nv, "seq", axis=2, tiled=True)
                    owner = off // cap
                    off = off - owner * cap    # offset within the shard
                    write = write * (owner == iq).astype(kbuf.dtype)
                upd = jax.lax.dynamic_slice(
                    kbuf, (0, 0, off, 0, 0), (Lp, r, Cg, Hkv, hd))
                kbuf = jax.lax.dynamic_update_slice(
                    kbuf, upd * (1 - write) + nk * write, (0, 0, off, 0, 0))
                upd = jax.lax.dynamic_slice(
                    vbuf, (0, 0, off, 0, 0), (Lp, r, Cg, Hkv, hd))
                vbuf = jax.lax.dynamic_update_slice(
                    vbuf, upd * (1 - write) + nv * write, (0, 0, off, 0, 0))

            rec = (valid & (s == S - 1)).astype(outs.dtype)
            cur = jax.lax.dynamic_slice(outs, (j, 0, 0, 0), (1, r, C, D))
            outs = jax.lax.dynamic_update_slice(
                outs, cur * (1 - rec) + y[None] * rec, (j, 0, 0, 0))

            perm = [(i, (i + 1) % S) for i in range(S)]
            state = jax.lax.ppermute(y, axis, perm)
            return (state, kbuf, vbuf, outs), None

        (_, kbuf, vbuf, outs), _ = jax.lax.scan(
            tick, (state0, kbuf, vbuf, outs0), jnp.arange(W + S - 1))
        outs = jax.lax.psum(outs * (s == S - 1), axis)
        return outs, {"k": kbuf, "v": vbuf}

    def f(params, kv, batch):
        W, R, C = batch["tokens"].shape
        cap = kv["k"].shape[2]
        PIPE_TRACE_EVENTS.append((cfg.name, W, cap, R, C, cp))
        from repro.core.chunked_step import token_nll_sum
        stage_layers = split_stages(params["layers"], n_stages)
        windows = jnp.asarray(win_np)
        x_mbs = params["embed"][batch["tokens"]]
        # "seq" shards every token dim (x/pos/seg dim 2, K/V capacity dim 2)
        # when cp > 1; in wide mode it joins the ROW sharding instead; with
        # neither it is unmentioned (replicated — bit-identical to the 2D
        # executor).
        if cp > 1:
            tok, kvs = P(None, "data", "seq"), P(axis, "data", "seq")
        elif wide:
            tok = P(None, ("data", "seq"))
            kvs = P(axis, ("data", "seq"))
        else:
            tok, kvs = P(None, "data"), P(axis, "data")
        outs, kv_out = shard_map(
            body, mesh=mesh,
            in_specs=(P(axis), P(axis), kvs, tok, tok, tok, tok, tok,
                      P(), P()),
            out_specs=(tok, kvs),
            check_vma=False,
        )(stage_layers, windows, kv, x_mbs, batch["positions"],
          batch["segment_ids"], batch["prefix_pos"], batch["prefix_seg"],
          batch["offsets"], batch["write_flags"])
        x = L.rms_norm(outs, params["ln_f"], cfg.norm_eps)
        logits = api._unembed(cfg, params, x)
        loss = token_nll_sum(
            logits.reshape(W * R, C, logits.shape[-1]),
            batch["labels"].reshape(W * R, C),
            batch["loss_mask"].reshape(W * R, C))
        return loss * batch["loss_scale"], kv_out

    return jax.jit(f)


def _tree_bytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree)
               if hasattr(x, "nbytes"))


def _run_wave_pipelined(cfg: ModelConfig, params, slots, *, k: int,
                        mesh, n_stages: int, loss_scale: float, grads,
                        stats: PipelineStats, blockwise_threshold: int,
                        axis: str = "pipe", cp: int = 1, wide: bool = False,
                        ring_overlap: bool = True):
    """Algorithm 2 over one lockstep wave of chunk slots, pipelined.

    slots: list of (R, C) stacked chunk batches (one row per DP rank, dummy
    rows fully masked). Windows of at most K slots run as rotation scans;
    only the last window's forward keeps residuals, earlier windows are
    re-forwarded right before their backward (F2). Returns (loss, grads).

    cp > 1: this wave rides the "seq" ring — the caller has already checked
    eligibility (C % cp == 0 and the per-rank StateStore shard holds whole
    chunk slots, cap/cp % C == 0). wide: packed cp=1 wave widened to
    dp * seq rows over the combined ("data", "seq") axes.
    """
    from repro.core import chunked_step as cs
    from repro.core.schedule_sim import rotation_windows

    n = len(slots)
    R, C = slots[0]["tokens"].shape
    S = n_stages
    cap = ss.prefix_capacity(n, C)
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim

    # prefix metadata per slot: pos/seg of slots < i (seg=0 => masked)
    meta = cs._prefix_meta_init(R, cap)
    metas = [meta]
    for i, b in enumerate(slots[:-1]):
        meta = cs._prefix_meta_write(meta, b, cfg, i * C)
        metas.append(meta)

    if cp > 1:
        kv_spec = P(axis, "data", "seq")
    elif wide:
        kv_spec = P(axis, ("data", "seq"))
    else:
        kv_spec = P(axis, "data")
    kv_sharding = NamedSharding(mesh, kv_spec)
    kv = jax.device_put(
        {"k": jnp.zeros((cfg.num_layers, R, cap, cfg.padded_num_kv_heads,
                         hd), dtype),
         "v": jnp.zeros((cfg.num_layers, R, cap, cfg.padded_num_kv_heads,
                         hd), dtype)},
        kv_sharding)
    stats.kv_store_bytes = max(stats.kv_store_bytes, _tree_bytes(kv))
    stats.wave_sizes.append(n)
    stats.kv_capacity_slots.append(cap // C if C else 0)

    f = _window_step_fn(cfg, mesh, S, blockwise_threshold, axis, cp, wide,
                        ring_overlap)
    scale = jnp.asarray(loss_scale, jnp.float32)

    def window_batch(g0, g1):
        b = {kk: jnp.stack([slots[g][kk] for g in range(g0, g1)])
             for kk in slots[0]}
        b["prefix_pos"] = jnp.stack([metas[g][0] for g in range(g0, g1)])
        b["prefix_seg"] = jnp.stack([metas[g][1] for g in range(g0, g1)])
        b["offsets"] = jnp.asarray([g * C for g in range(g0, g1)], jnp.int32)
        b["write_flags"] = jnp.asarray(
            [1 if g < n - 1 else 0 for g in range(g0, g1)], jnp.int32)
        b["loss_scale"] = scale
        return b

    wins = rotation_windows(n, k)
    ranges, g0 = [], 0
    for w in wins:
        ranges.append((g0, g0 + w))
        g0 += w

    total_loss = 0.0
    kept_vjp = None
    recompute0 = stats.recompute_calls
    for wi, (g0, g1) in enumerate(ranges):
        W = g1 - g0
        batch_w = window_batch(g0, g1)
        if wi == len(ranges) - 1:        # keep residuals for the last window
            (loss_w, kv), kept_vjp = jax.vjp(
                lambda p, kv_in, b=batch_w: f(p, kv_in, b), params, kv)
            stats.max_live_residuals = max(stats.max_live_residuals, W)
            stats.peak_residual_bytes = max(stats.peak_residual_bytes,
                                            _tree_bytes(kept_vjp))
        else:
            loss_w, kv = f(params, kv, batch_w)
        total_loss = total_loss + loss_w
        stats.forward_calls += W
        stats.makespan_units += (W + S - 1)
        stats.useful_units += 3.0 * W * S
        stats.scans.append(("F", W, W + S - 1))

    kv_full = kv
    one = jnp.ones((), jnp.float32)
    g_kv = jax.tree.map(jnp.zeros_like, kv_full)
    vjp_fn = None
    for wi in reversed(range(len(ranges))):
        g0, g1 = ranges[wi]
        W = g1 - g0
        if wi == len(ranges) - 1:
            vjp_fn, kept_vjp = kept_vjp, None
        else:                            # F2: recompute right before backward
            # drop the consumed window's closure BEFORE building the next
            # one, so at most K chunks' residuals are ever live
            vjp_fn = None
            batch_w = window_batch(g0, g1)
            (_, _), vjp_fn = jax.vjp(
                lambda p, kv_in, b=batch_w: f(p, kv_in, b), params, kv_full)
            stats.recompute_calls += W
            stats.max_live_residuals = max(stats.max_live_residuals, W)
            stats.peak_residual_bytes = max(stats.peak_residual_bytes,
                                            _tree_bytes(vjp_fn))
            stats.makespan_units += (W + S - 1)
            stats.recompute_units += 1.0 * W * S
            stats.scans.append(("F2", W, W + S - 1))
        gp, g_kv = vjp_fn((one, g_kv))
        grads = ss.tree_add(grads, gp)
        stats.backward_calls += W
        stats.makespan_units += 2 * (W + S - 1)
        stats.scans.append(("B", W, W + S - 1))
    if cp > 1:
        rec = stats.recompute_calls - recompute0
        stats.ring_steps += dp_balance.ring_hops(n + rec, n, cp,
                                                 cfg.num_layers)
        if ring_overlap:
            stats.overlapped_hops += dp_balance.overlapped_ring_hops(
                n + rec, n, cp, cfg.num_layers)
    return total_loss, grads


def run_batch_pipelined(cfg: ModelConfig, params, batch, plan=None,
                        mesh=None, *, k: int = None,
                        blockwise_threshold: int = None,
                        plan_policy: str = None, axis: str = "pipe",
                        cp_threshold: int = None):
    """One training micro-iteration on a (data x pipe [x seq]) mesh, driven
    by an ExecutionPlan: ``run_batch_pipelined(cfg, params,
    (groups, standalone), plan)``. (The legacy ``(cfg, params, groups,
    standalone, mesh, k=..., ...)`` signature still works under
    DeprecationWarning — `chunked_step.coerce_plan`.)

    The plan's waves are stacked (R, C) slot batches; the rotation
    pipelines each wave's chunk stream over ``pipe`` with the K-retention
    schedule (windows of at most K slots per scan, earlier windows F2-
    recomputed right before their backward). Numerically equivalent to the
    single-device ``run_batch`` (tests/test_pipeline2d.py: <=1e-5,
    including K < N recompute) under ANY plan.

    Per-wave cp routing on a mesh with a "seq" axis: cp > 1 waves shard
    chunk tokens and the per-stage StateStore capacity over "seq" —
    context parallelism composed INSIDE the rotation's shard_map; cp=1
    waves widened by the solver to dp * seq slots shard ROWS over the
    combined ("data", "seq") axes instead (no ring hops). Waves whose
    per-rank StateStore shard would split a chunk slot (cap/cp not a
    multiple of C) fall back to seq-replication.
    """
    if cfg.family != "dense":
        raise NotImplementedError(
            f"run_batch_pipelined: config {cfg.name!r} requests family "
            f"{cfg.family!r}, but the pipeline executor supports only "
            "{'dense'}: split_stages slices a uniform (L, ...) layer slab, "
            "which moe/ssm/hybrid/audio/vlm param trees don't provide. Run "
            "this config through run_batch (single-device or data-parallel) "
            "instead, or set pp=1 in the ExecutionPlan.")
    from repro.core import chunked_step as cs

    groups, standalone, plan = cs.coerce_plan(
        batch, plan, mesh, k=k, blockwise_threshold=blockwise_threshold,
        plan_policy=plan_policy, cp_threshold=cp_threshold,
        where="run_batch_pipelined")
    mesh = plan.mesh
    S = sharding.pipe_size(mesh)
    if cfg.num_layers % S:
        raise ValueError(f"num_layers={cfg.num_layers} not divisible by "
                         f"pipe={S}")
    D = sharding.dp_size(mesh)
    seq = sharding.seq_size(mesh)
    scale = cs._batch_loss_scale(groups, standalone)

    params = sharding.pipeline_put(mesh, params)
    grads, total_loss = None, 0.0
    stats = PipelineStats(n_stages=S)
    for wave in plan.waves:
        cp = wave.cp
        if cp > 1 and cp != seq:
            raise ValueError(f"wave cp={cp} != mesh seq size {seq}: ring "
                             "waves run at exactly the \"seq\" axis width")
        slots = cs.stack_wave_slots(cfg, wave.slots, mesh, cp=cp)
        n = len(slots)
        R, C = slots[0]["tokens"].shape
        cap = ss.prefix_capacity(n, C)
        ring = (cp > 1 and C % cp == 0
                and (cap == 0 or (cap // cp) % C == 0))
        wide = (cp == 1 and seq > 1 and R % (D * seq) == 0)
        stats.wave_cps.append(cp if ring else 1)
        l, grads = _run_wave_pipelined(
            cfg, params, slots, k=plan.k, mesh=mesh, n_stages=S,
            loss_scale=scale, grads=grads, stats=stats,
            blockwise_threshold=plan.blockwise_threshold, axis=axis,
            cp=(cp if ring else 1), wide=wide,
            ring_overlap=plan.ring_overlap)
        total_loss = total_loss + l
    return total_loss, grads, stats
