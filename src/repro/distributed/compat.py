"""JAX version-compatibility shims for the SPMD executors.

`shard_map` graduated from `jax.experimental.shard_map` (kwarg `check_rep`)
to `jax.shard_map` (kwarg `check_vma`), and `jax.lax.pcast` only exists
under the new varying-manual-axes type system. Route through here so the
executors run on both API generations.
"""
import jax

if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:                                        # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    kw = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def pcast_varying(x, axes):
    """`jax.lax.pcast(x, axes, to="varying")` where it exists; identity under
    the pre-VMA type system (replication there is checked by value, not by
    type, and `check_rep=False` regions skip the check entirely)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to="varying")
    return x
