"""Per-architecture GSPMD sharding rules (divisibility-aware).

Megatron-style mapping onto the ("pod","data","model") mesh:
  * TP over "model": attention q-proj out dim, kv-proj out dim (when the KV
    width divides — GQA KV otherwise replicates within TP groups, standard
    practice), FFN hidden dim, expert dim of MoE weights (expert parallelism),
    vocab dim of the unembedding, mamba inner dim.
  * DP over "data" (x "pod" multi-pod): batch dim of every activation.
  * FSDP ("zero-3") over "data" for tensors still larger than
    ``fsdp_threshold`` bytes per model shard — required for the ≥398B archs.
  * SP (sequence sharding) is applied for long-context shapes by sharding the
    sequence dim of decode caches over "model" when KV heads cannot split.

All functions return pytrees of PartitionSpec matching the corresponding
param/cache/batch pytrees.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP_THRESHOLD = 64 * 1024 * 1024       # bytes per model-shard


def _div(n, by):
    return by > 0 and n % by == 0


def mesh_sizes(mesh):
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    return d.get("pod", 1), d.get("data", 1), d.get("model", 1)


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _maybe_fsdp(spec_list, shape, mesh, dtype_bytes=2, *,
                threshold=FSDP_THRESHOLD):
    """Add 'data' sharding on the largest still-unsharded divisible dim if the
    per-model-shard tensor is large (ZeRO-3)."""
    _, dsz, msz = mesh_sizes(mesh)
    per_shard = np.prod(shape) * dtype_bytes
    for sp in spec_list:
        if sp == "model":
            per_shard //= msz
    if per_shard <= threshold:
        return spec_list
    # largest unsharded divisible dim
    cands = [(shape[i], i) for i, sp in enumerate(spec_list)
             if sp is None and _div(shape[i], dsz)]
    if not cands:
        return spec_list
    _, idx = max(cands)
    spec_list = list(spec_list)
    spec_list[idx] = "data"
    return spec_list


def param_specs(cfg: ModelConfig, params_shape, mesh, *,
                fsdp_threshold: int = FSDP_THRESHOLD):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape).

    fsdp_threshold: per-model-shard bytes above which a tensor additionally
    shards over the data axis (ZeRO-3). Training needs it whenever
    params+optimizer exceed HBM; inference passes a much higher threshold —
    re-gathering weights per layer is pure collective waste when the bf16
    weights already fit (measured on yi-34b prefill: §Perf iteration 2)."""
    _, dsz, msz = mesh_sizes(mesh)
    hd = cfg.resolved_head_dim

    def leaf(path, x):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = names[-1]
        shape = x.shape
        spec = [None] * len(shape)

        def set_if(axis_idx, mesh_axis, size):
            if _div(shape[axis_idx], size):
                spec[axis_idx] = mesh_axis

        if name == "embed":
            set_if(1, "model", msz)                    # d_model-sharded table
        elif name == "unembed":
            set_if(len(shape) - 1, "model", msz)       # vocab-parallel logits
        elif name in ("wq", "wo"):
            # (L?, D, Hq*hd) / (L?, Hq*hd, D): shard along WHOLE heads only —
            # splitting inside head_dim makes every attention contraction
            # partial (measured: 57 TB of per-block score all-reduces on yi)
            if cfg.padded_num_heads % msz == 0:
                axis = len(shape) - 1 if name == "wq" else len(shape) - 2
                set_if(axis, "model", msz)
        elif name in ("wk", "wv"):
            if cfg.padded_num_kv_heads % msz == 0:
                set_if(len(shape) - 1, "model", msz)
        elif name == "bq":
            if cfg.padded_num_heads % msz == 0:
                set_if(len(shape) - 1, "model", msz)
        elif name in ("bk", "bv"):
            if cfg.padded_num_kv_heads % msz == 0:
                set_if(len(shape) - 1, "model", msz)
        elif name in ("w_gate", "w_up", "w_in"):
            if cfg.num_experts and len(shape) >= 3 and "moe" in str(names):
                # (L?, E, D, F): expert parallelism on E
                set_if(len(shape) - 3, "model", msz)
            else:
                set_if(len(shape) - 1, "model", msz)   # FFN hidden dim
        elif name in ("w_down", "w_out"):
            if cfg.num_experts and len(shape) >= 3 and "moe" in str(names):
                set_if(len(shape) - 3, "model", msz)
            else:
                set_if(len(shape) - 2, "model", msz)
        elif name == "b_in":
            set_if(len(shape) - 1, "model", msz)
        elif name == "in_proj":
            set_if(len(shape) - 1, "model", msz)       # mamba fused proj
        elif name == "out_proj":
            set_if(len(shape) - 2, "model", msz)       # (L?, DI, D)
        elif name == "router":
            pass                                        # small, replicated
        # norms / conv / A_log / dt_bias / D / pos tables: replicated

        spec = _maybe_fsdp(spec, shape, mesh,
                           jnp.dtype(x.dtype).itemsize,
                           threshold=fsdp_threshold)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def _pad_spec(spec: P, ndim: int) -> P:
    s = (list(spec) + [None] * ndim)[:ndim]
    return P(*s)


def adamw_opt_specs(pspecs):
    """m/v are param-shaped fp32 -> inherit param sharding exactly."""
    return {"m": pspecs, "v": pspecs, "step": P()}


def adafactor_opt_specs(pspecs, params_shape):
    """Factored slots: vr drops the last dim, vc drops the second-last."""
    def slot(spec, x):
        if len(x.shape) >= 2:
            return {"vr": P(*list(_pad_spec(spec, len(x.shape)))[:-1]),
                    "vc": P(*(list(_pad_spec(spec, len(x.shape)))[:-2]
                              + list(_pad_spec(spec, len(x.shape)))[-1:]))}
        return {"v": _pad_spec(spec, len(x.shape))}

    return {"slots": jax.tree.map(slot, pspecs, params_shape,
                                  is_leaf=lambda s: isinstance(s, P)),
            "step": P()}


def dp_size(mesh) -> int:
    """Total data-parallel degree of a mesh (pod x data)."""
    pod, data, _ = mesh_sizes(mesh)
    return pod * data


def pipe_size(mesh) -> int:
    """Size of the pipeline-stage axis (1 when the mesh has none)."""
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("pipe", 1)


def seq_size(mesh) -> int:
    """Size of the context-parallel ("seq") axis (1 when the mesh has none).

    CP placement contract: params and optimizer state replicate over "seq"
    (every CP rank applies the full layer stack to its token shard); batch
    token dims shard over "seq" (`batch_specs`); StateStore K/V buffers shard
    their capacity dim over "seq" — each rank holds the contiguous
    [i*cap/cp, (i+1)*cap/cp) ring shard that circulates via ppermute inside
    the CP executors."""
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("seq", 1)


def pipeline_param_specs(tree, mesh):
    """Stage-sharded placement for the 2D (data x pipe) training mesh.

    Every leaf under a ``layers`` subtree is layer-stacked (leading dim L);
    sharding that dim over ``pipe`` puts contiguous L/S layer slabs on each
    stage — exactly the `split_stages` blocks the rotation executor consumes,
    with no gather. Everything else (embed / unembed / ln_f, optimizer
    scalars) replicates. Works for params and for param-shaped optimizer
    slots (the ``layers`` path component appears at any depth)."""
    psz = pipe_size(mesh)

    def leaf(path, x):
        names = [getattr(kk, "key", getattr(kk, "name", None)) for kk in path]
        if ("layers" in names and getattr(x, "ndim", 0) >= 1
                and x.shape[0] % psz == 0):
            return P("pipe", *([None] * (x.ndim - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(leaf, tree)


def pipeline_put(mesh, tree):
    """Place params (or param-shaped opt state) per `pipeline_param_specs`.
    No-op when the first layers leaf is already resident with that sharding."""
    specs = pipeline_param_specs(tree, mesh)
    flat = jax.tree.leaves(tree)
    flat_s = [NamedSharding(mesh, sp) for sp in jax.tree.leaves(specs)]
    if flat and all(getattr(x, "sharding", None) == s
                    for x, s in zip(flat, flat_s)):
        return tree
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)), tree, specs)


def replicate_put(mesh, tree):
    """Place a pytree on the mesh fully replicated (params, opt state).
    No-op when the tree is already resident-replicated there."""
    s = NamedSharding(mesh, P())
    leaves = jax.tree.leaves(tree)
    if leaves and getattr(leaves[0], "sharding", None) == s:
        return tree          # placed by an earlier step (train keeps state
                             # resident); leaves share one placement
    return jax.tree.map(lambda x: jax.device_put(x, s), tree)


def dp_put(cfg: ModelConfig, batch, mesh):
    """Place a chunk-batch pytree on the mesh with batch dims sharded over
    the DP axes (via `batch_specs`) — row r of the batch lives on DP rank r,
    which is what makes the planner's rank assignment physical."""
    specs = batch_specs(cfg, batch, mesh)
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        batch, specs)


def wave_specs(cfg: ModelConfig, batch_shape, mesh, cp: int):
    """PartitionSpecs for ONE planned wave's (R, C) stacked chunk batch, at
    the wave's own context-parallel degree (ExecutionPlan.waves[i].cp):

      * cp > 1 (ring wave): rows over the DP axes, token dim (dim 1) over
        "seq" — each CP rank holds its token shard, K/V will circulate as
        the ppermute ring. R == dp_size rows.
      * cp == 1 on a mesh WITH a "seq" axis (packed wave): rows over the
        combined (data..., "seq") axes — the planner widened the wave to
        dp_size * seq_size slots so the would-be ring ranks each run their
        own unit instead; tokens stay whole and no ring hops are paid.
      * cp == 1, no "seq" axis: plain DP row sharding (== `batch_specs`).

    Rows that don't divide the target axes replicate (the planner always
    emits exact widths; this is belt-and-suspenders for hand-built plans).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    seq = sizes.get("seq", 1)
    row_axes = dp_axes(mesh)
    if cp <= 1 and seq > 1:
        row_axes = tuple(row_axes) + ("seq",)
    total_rows = int(np.prod([sizes[a] for a in row_axes]))

    def leaf(path, x):
        name = getattr(path[-1], "key", None)
        if name in ("loss_scale",) or x.ndim == 0:
            return P()
        first = row_axes if _div(x.shape[0], total_rows) else None
        rest = [None] * (x.ndim - 1)
        if cp > 1 and x.ndim >= 2 and _div(x.shape[1], seq):
            rest[0] = "seq"
        return P(first, *rest)

    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def wave_put(cfg: ModelConfig, batch, mesh, cp: int):
    """Place one wave's stacked chunk batch per `wave_specs` — the
    ExecutionPlan's per-wave cp decision made physical."""
    specs = wave_specs(cfg, batch, mesh, cp)
    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        batch, specs)


def batch_specs(cfg: ModelConfig, batch_shape, mesh):
    """Batch dims over DP; with a context-parallel "seq" axis the token dim
    (dim 1 of every (B, C[, ...]) chunk array) additionally shards over it,
    matching the CP executors' shard_map in_specs so dp_put lands the data
    where the ring will read it."""
    dp = dp_axes(mesh)
    cp = seq_size(mesh)

    def leaf(path, x):
        name = getattr(path[-1], "key", None)
        if name in ("loss_scale",):
            return P()
        if x.ndim == 0:
            return P()
        bsz = x.shape[0]
        total_dp = int(np.prod([dict(zip(mesh.axis_names,
                                         mesh.devices.shape))[a] for a in dp]))
        first = dp if _div(bsz, total_dp) else None
        rest = [None] * (x.ndim - 1)
        if cp > 1 and x.ndim >= 2 and _div(x.shape[1], cp):
            rest[0] = "seq"
        return P(first, *rest)

    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh, batch: int):
    """Decode caches: batch over DP when divisible; KV heads over model when
    divisible, else the sequence dim over model (sequence-parallel cache)."""
    dp = dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total_dp = int(np.prod([sizes[a] for a in dp]))
    msz = sizes["model"]
    b_ax = dp if _div(batch, total_dp) else None

    def leaf(path, x):
        name = getattr(path[-1], "key", None)
        shape = x.shape
        if name in ("k", "v", "ck", "cv", "k_local", "v_local",
                    "k_global", "v_global"):
            # (L, B, S, Hkv, hd)
            spec = [None, b_ax, None, None, None]
            if _div(shape[3], msz):
                spec[3] = "model"
            elif _div(shape[2], msz):
                spec[2] = "model"
            if b_ax is None and spec[2] is None and _div(shape[2], total_dp):
                spec[2] = dp if spec[3] == "model" else dp
            return P(*spec)
        if name == "ssm":
            # (..., B, H, P, S)
            spec = [None] * len(shape)
            spec[-4] = b_ax
            if _div(shape[-3], msz):
                spec[-3] = "model"
            return P(*spec)
        if name == "conv":
            spec = [None] * len(shape)
            spec[-3] = b_ax
            if _div(shape[-1], msz):
                spec[-1] = "model"
            return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)
