"""Synthetic long-tail dataset calibrated to the paper's Tables 1-2.

The paper's evaluation dataset (Table 2):
    <1K: 98.17%   <4K: 99.72%   <8K: 99.83%   <32K: 99.92%   <128K: 99.98%
    longest: 256K
LMSysChat1M (Table 1):
    <1K: 90.499%  <4K: 99.539%  <8K: 99.908%  <32K: 99.987%  <128K: 99.996%
    longest: 303K

We sample from a piecewise distribution whose bucket masses match those CDFs
exactly (within-bucket lengths log-uniform), so every statistic the paper
derives from the distribution (memory footprints, chunk counts, bubble
ratios, Fig. 8 speedups) is reproducible. Tokens are uniform ints — the
systems behaviour only depends on lengths.
"""
from __future__ import annotations

import numpy as np

# (upper_bound_exclusive, cdf_at_bound)
PAPER_EVAL_CDF = [(1_024, 0.9817), (4_096, 0.9972), (8_192, 0.9983),
                  (32_768, 0.9992), (131_072, 0.9998), (262_144, 1.0)]
LMSYS_CDF = [(1_024, 0.90499), (4_096, 0.99539), (8_192, 0.99908),
             (32_768, 0.99987), (131_072, 0.99996), (303_000, 1.0)]


class LongTailSampler:
    def __init__(self, cdf=None, min_len: int = 16, seed: int = 0,
                 max_len: int = None):
        self.cdf = cdf or PAPER_EVAL_CDF
        self.min_len = min_len
        self.max_len = max_len      # context-length cutoff (paper: exclude)
        self.rng = np.random.RandomState(seed)

    def sample_length(self) -> int:
        while True:
            u = self.rng.rand()
            lo, prev = self.min_len, 0.0
            for ub, c in self.cdf:
                if u <= c:
                    # log-uniform within the bucket
                    l = int(np.exp(self.rng.uniform(np.log(lo), np.log(ub))))
                    break
                lo, prev = ub, c
            else:
                l = self.cdf[-1][0]
            l = max(self.min_len, l)
            if self.max_len is None or l <= self.max_len:
                return l

    def sample_batch_lengths(self, n: int) -> list:
        return [self.sample_length() for _ in range(n)]

    def sample_batch(self, n: int, vocab_size: int):
        """-> ({seq_id: np.ndarray tokens}, {seq_id: length})"""
        lengths = {i: self.sample_length() for i in range(n)}
        seqs = {i: self.rng.randint(1, vocab_size, size=l).astype(np.int32)
                for i, l in lengths.items()}
        return seqs, lengths

    def bucket_stats(self, n: int = 100_000):
        lens = np.array([self.sample_length() for _ in range(n)])
        out = {}
        for ub, _ in self.cdf:
            out[ub] = float((lens < ub).mean())
        out["max"] = int(lens.max())
        return out
