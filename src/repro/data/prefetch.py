"""Host-side async prefetch — overlap chunk construction with device compute.

Algorithm 1 (sampling, chunk construction, bin packing, materialization into
padded numpy arrays) is pure host work; the device is idle while it runs and
vice versa. `Prefetcher` moves that work to a background thread with a
bounded queue (double-buffering by default): while the device executes step
``t``'s Algorithm 2, the thread is already building step ``t+1``'s chunk
batches.

The producer runs entirely in numpy — device transfer (jnp.asarray /
device_put) stays on the consumer thread, keeping JAX dispatch
single-threaded. Exceptions in the producer are captured and re-raised on
the consumer thread at the matching `next()` call, so failures surface at
the step that needed the data instead of dying silently.
"""
from __future__ import annotations

import queue
import threading


class _Stop:
    pass


class _Error:
    def __init__(self, exc):
        self.exc = exc


class Prefetcher:
    """Iterate a producer callable on a background thread, ``depth`` items
    ahead.

    producer: callable (step: int) -> item, run for steps [0, n_steps) —
              must be thread-safe with respect to the consumer (the train
              driver only touches device state, the producer only host RNG
              and numpy buffers).
    depth:    queue bound; 2 = classic double buffering.
    """

    def __init__(self, producer, n_steps: int, *, depth: int = 2,
                 name: str = "chunk-prefetch"):
        assert depth >= 1
        self._q = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._n = n_steps

        def work():
            try:
                for step in range(n_steps):
                    if self._stop.is_set():
                        return
                    item = producer(step)
                    while not self._stop.is_set():
                        try:
                            self._q.put(item, timeout=0.1)
                            break
                        except queue.Full:
                            continue
            except BaseException as e:        # re-raised on the consumer side
                self._q.put(_Error(e))
                return
            self._q.put(_Stop())

        self._thread = threading.Thread(target=work, daemon=True, name=name)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, _Stop):
            raise StopIteration
        if isinstance(item, _Error):
            raise item.exc
        return item

    def close(self):
        """Stop the producer and drop anything buffered."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def synchronous(producer, n_steps: int):
    """Drop-in replacement for Prefetcher with depth=0 semantics (no thread,
    no overlap) — the --prefetch 0 escape hatch for debugging."""
    return (producer(step) for step in range(n_steps))
