"""Clean sibling of custom_vjp_bad: correctly paired custom_vjp in both the
plain and nondiff_argnums forms (mirrors kernels/chunked_attention.py)."""
import functools

import jax


@jax.custom_vjp
def attn(q, k, v):
    return q @ k.T @ v


def attn_fwd(q, k, v):
    out = q @ k.T @ v
    return out, (q, k, v)


def attn_bwd(res, do):
    q, k, v = res
    return do @ (k.T @ v).T, (q.T @ do @ v.T).T, (q @ k.T).T @ do


attn.defvjp(attn_fwd, attn_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def scaled(x, w, static_scale):
    return x @ w * static_scale


def scaled_fwd(x, w, static_scale):
    return x @ w * static_scale, (x, w)


def scaled_bwd(static_scale, res, do):
    x, w = res
    # 3 primal args - 1 nondiff -> 2 cotangents
    return do @ w.T * static_scale, x.T @ do * static_scale


scaled.defvjp(scaled_fwd, scaled_bwd)
