"""Seeded CF-VJP violations: unwired primal, bwd arity skew, residual
pack/unpack skew, dead nondiff index."""
import functools

import jax
import jax.numpy as jnp


@jax.custom_vjp
def never_wired(x, y):           # CF-VJP01: no defvjp call anywhere
    return x * y


@jax.custom_vjp
def short_bwd(q, k, v, scale):
    return q @ k.T * scale + v


def short_bwd_fwd(q, k, v, scale):
    out = q @ k.T * scale + v
    return out, (q, k, scale)


def short_bwd_bwd(res, do):
    q, k, scale = res
    # CF-VJP02: 4 primal args, zero nondiff -> must return 4 cotangents
    return do @ k * scale, do.T @ q * scale, do


short_bwd.defvjp(short_bwd_fwd, short_bwd_bwd)


@jax.custom_vjp
def skewed_residuals(x, w):
    return x @ w


def skewed_residuals_fwd(x, w):
    return x @ w, (x, w, jnp.float32(1.0))


def skewed_residuals_bwd(res, do):
    x, w = res                   # CF-VJP03: fwd packed 3, bwd unpacks 2
    return do @ w.T, x.T @ do


skewed_residuals.defvjp(skewed_residuals_fwd, skewed_residuals_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def dead_nondiff(x, w, b, s):    # CF-VJP05: index 4 out of range(4)
    return x @ w + b * s


def dead_nondiff_fwd(x, w, b, s):
    return x @ w + b * s, (x, w, s)


def dead_nondiff_bwd(flag, res, do):
    x, w, s = res
    return do @ w.T, x.T @ do, do * s


dead_nondiff.defvjp(dead_nondiff_fwd, dead_nondiff_bwd)
