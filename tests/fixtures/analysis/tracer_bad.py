"""Seeded CF-TR violations: Python control flow on traced values, and a
host-side jnp value closed over into a shard_map body."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


@jax.jit
def branch_on_traced(x):
    # CF-TR01: jnp.any returns a tracer under jit — needs lax.cond/jnp.where
    if jnp.any(x > 0):
        return x * 2
    return x


def _kernel(x_ref, o_ref):
    # CF-TR01: program_id is a tracer — this must be pl.when
    if pl.program_id(0) == 0:
        o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[...] += x_ref[...]


def launch(x, block):
    B, T = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(B, T // block),
        in_specs=[pl.BlockSpec((1, block), lambda b, it: (b, it))],
        out_specs=pl.BlockSpec((1, block), lambda b, it: (b, it)),
        out_shape=jax.ShapeDtypeStruct((B, T), x.dtype),
    )(x)


def closes_over_host_value(mesh, x):
    scale = jnp.arange(8, dtype=jnp.float32)     # host-side device array

    def body(xs):
        # CF-TR02: `scale` arrives replicated, bypassing in_specs
        return xs * scale

    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))(x)
