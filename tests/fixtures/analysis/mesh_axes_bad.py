"""Seeded CF-AX01 violations: axis strings outside the fixture registry
("data", "pipe", "model", "seq")."""
import jax
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def typo_in_partition_spec(x, mesh):
    # "dta" is the classic silent-replication typo
    spec = P("dta", None)
    return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))


def typo_in_collective(x):
    return jax.lax.psum(x, "seqq")


def typo_in_mesh_ctor():
    return jax.make_mesh((2, 2), ("data", "pip"))


def typo_in_shard_map_specs(f, mesh, x):
    return shard_map(f, mesh=mesh, in_specs=(P("data", "sqe"),),
                     out_specs=P("data"))(x)


def typo_in_ppermute(x, cp):
    perm = [(i, (i + 1) % cp) for i in range(cp)]
    return jax.lax.ppermute(x, "se", perm)
