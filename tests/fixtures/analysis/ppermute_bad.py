"""Seeded CF-RING violations: ppermute permutations that are not total
bijections over the axis."""
import jax


def non_cyclic_shift(x, cp):
    # the motivating near-miss: stops at cp-1, rank cp-1's buffer is dropped
    # and rank 0 never receives — sources {0..cp-2} != destinations {1..cp-1}
    perm = [(i, i + 1) for i in range(cp - 1)]
    return jax.lax.ppermute(x, "seq", perm)


def even_size_collision(x, cp):
    # bijective for odd cp only: at cp=4, 0->2 and 2->0 but 1->3 and 3->1 is
    # fine... while (i * 2) % cp collapses {0, 2} -> 0 at cp=4
    perm = [(i, (i * 2) % cp) for i in range(cp)]
    return jax.lax.ppermute(x, "seq", perm)


def literal_duplicate_destination(x):
    return jax.lax.ppermute(x, "seq", perm=[(0, 1), (1, 1), (2, 0)])


def clamped_shift(x, cp):
    # min() clamp makes the last two ranks both target cp-1
    perm = [(i, min(i + 1, cp - 1)) for i in range(cp)]
    return jax.lax.ppermute(x, "seq", perm)
