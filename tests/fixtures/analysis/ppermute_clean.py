"""Clean sibling of ppermute_bad: total cycles in every supported spelling
(comprehension, closure-bound name, literal, reverse rotation)."""
import jax


def forward_ring(x, cp):
    perm = [(i, (i + 1) % cp) for i in range(cp)]
    return jax.lax.ppermute(x, "seq", perm)


def reverse_ring(x, cp):
    return jax.lax.ppermute(x, "seq", [(i, (i - 1) % cp) for i in range(cp)])


def closure_bound_ring(cp):
    # the chunked_attention idiom: perm bound once, used inside a helper
    perm = [(i, (i + 1) % cp) for i in range(cp)]

    def rotate(*xs):
        return tuple(jax.lax.ppermute(x, "seq", perm) for x in xs)

    return rotate


def literal_swap(x):
    return jax.lax.ppermute(x, "seq", [(0, 1), (1, 0)])


def dynamic_perm(x, perm):
    # unresolvable statically: must NOT be flagged
    return jax.lax.ppermute(x, "seq", perm)
