"""Fixture axis registry — chunklint resolves MESH_AXES from this file's
AST exactly as it does from src/repro/launch/mesh.py."""

MESH_AXES = ("data", "pipe", "model", "seq")
