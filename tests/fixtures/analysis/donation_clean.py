"""Clean sibling of donation_bad: donated names rebound by the call (the
train.py idiom), and non-donated args freely reused."""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0, 2))
def step(params, batch, opt):
    g = jax.tree.map(lambda p: p * batch.mean(), params)
    new_params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
    return new_params, opt


def train_loop(params, batches, opt):
    for batch in batches:
        params, opt = step(params, batch, opt)   # rebound: fresh buffers
    return params, opt


def reuse_non_donated(params, batch, opt):
    params, opt = step(params, batch, opt)
    return params, opt, batch.sum()              # batch (argnum 1) not donated
