"""Clean sibling of pallas_bad: the decode_attention shapes — grid and
grid_spec forms, scalar prefetch refs threaded into every index map."""
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def plain_grid(x, block):
    B, T, D = x.shape
    grid = (B, T // block, D // 128)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block, 128), lambda b, it, id_: (b, it, id_)),
            pl.BlockSpec(memory_space=pltpu.SMEM),      # no index map: fine
        ],
        out_specs=pl.BlockSpec((1, block, 128),
                               lambda b, it, id_: (b, it, id_)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
    )(x, x)


def _prefetch_kernel(tbl_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def prefetch_grid_spec(x, tables, block):
    B, T, D = x.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, T // block),
        in_specs=[
            pl.BlockSpec((1, block, D), lambda b, it, tbl: (tbl[b], it, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, D),
                               lambda b, it, tbl: (b, it, 0)),
    )
    return pl.pallas_call(
        _prefetch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
    )(tables, x)
