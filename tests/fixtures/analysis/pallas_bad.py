"""Seeded CF-PL violations: index-map arity, out-rank skew, operand count."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def wrong_index_map_arity(x, block):
    B, T, D = x.shape
    grid = (B, T // block, D // 128)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            # CF-PL01: 3 grid axes, lambda takes 2
            pl.BlockSpec((1, block, 128), lambda b, it: (b, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, 128),
                               lambda b, it, id_: (b, it, id_)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
    )(x)


def _prefetch_kernel(tbl_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def missing_prefetch_ref(x, tables, block):
    B, T, D = x.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, T // block),
        in_specs=[
            # CF-PL01: 2 grid axes + 1 scalar-prefetch ref = 3, lambda takes 2
            pl.BlockSpec((1, block, D), lambda b, it: (b, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, D),
                               lambda b, it, tbl: (b, it, 0)),
    )
    return pl.pallas_call(
        _prefetch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
    )(tables, x)


def wrong_out_rank(x, block):
    B, T, D = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(B, T // block),
        in_specs=[pl.BlockSpec((1, block, D), lambda b, it: (b, it, 0))],
        # CF-PL02: block shape rank 2 vs out_shape rank 3
        out_specs=pl.BlockSpec((1, block), lambda b, it: (b, it)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
    )(x)


def wrong_operand_count(x, y, block):
    B, T, D = x.shape
    kernel = functools.partial(_kernel)
    # CF-PL03: one in_spec, two operands
    return pl.pallas_call(
        kernel,
        grid=(B, T // block),
        in_specs=[pl.BlockSpec((1, block, D), lambda b, it: (b, it, 0))],
        out_specs=pl.BlockSpec((1, block, D), lambda b, it: (b, it, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
    )(x, y)
