"""Clean sibling of tracer_bad: static-value branching, pl.when, and
shard_map operands threaded through in_specs."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


@jax.jit
def branch_on_static(x, *, flag=True):
    if flag:                     # static Python bool: fine under jit
        return jnp.where(x > 0, x * 2, x)
    return x


def _kernel(x_ref, o_ref):
    ik = pl.program_id(0)

    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...]


def launch(x, block):
    B, T = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(B, T // block),
        in_specs=[pl.BlockSpec((1, block), lambda b, it: (b, it))],
        out_specs=pl.BlockSpec((1, block), lambda b, it: (b, it)),
        out_shape=jax.ShapeDtypeStruct((B, T), x.dtype),
    )(x)


def passes_operands(mesh, x):
    scale = jnp.arange(8, dtype=jnp.float32)

    def body(xs, sc):            # scale is an operand with its own spec
        return xs * sc

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P()),
                     out_specs=P("data"))(x, scale)
