"""Seeded CF-DN01 violations: donated buffers referenced after the call."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnums=(0, 2))
def step(params, batch, opt):
    g = jax.tree.map(lambda p: p * batch.mean(), params)
    new_params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
    return new_params, opt


def read_after_donation(params, batch, opt):
    new_params, new_opt = step(params, batch, opt)
    # CF-DN01: params' buffer was donated to step and is deleted now
    norm = jax.tree.map(jnp.linalg.norm, params)
    return new_params, new_opt, norm


def loop_without_rebinding(params, batches, opt):
    for batch in batches:
        # CF-DN01: next iteration re-donates the same dead buffers
        out = step(params, batch, opt)
    return out
