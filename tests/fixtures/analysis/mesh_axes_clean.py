"""Clean sibling of mesh_axes_bad: every axis literal is registered, and
axis-valued *variables* (unknowable statically) are left alone."""
import jax
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map

AXIS = "seq"


def registered_axes(f, mesh, x):
    return shard_map(f, mesh=mesh,
                     in_specs=(P("data", AXIS), P(None, ("data", "seq"))),
                     out_specs=P("data"))(x)


def registered_collective(x, cp):
    perm = [(i, (i + 1) % cp) for i in range(cp)]
    x = jax.lax.ppermute(x, "seq", perm)
    return jax.lax.psum(x, axis_name="data")


def registered_mesh():
    return jax.make_mesh((2, 2, 2), ("data", "pipe", "seq"))
