"""Checkpoint round-trips: treedef validation (a structure mismatch with an
equal leaf count must raise, not silently restore leaves into the wrong
slots) and train.py save -> --resume continuation equivalence."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig, TrainConfig
from repro.launch.train import train


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    p = str(tmp_path / "ck.msgpack")
    save_checkpoint(p, tree, step=7)
    out, step = restore_checkpoint(p, tree)
    assert step == 7
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_treedef_mismatch_same_leaf_count_raises(tmp_path):
    """Two leaves either way, identical shapes — before the fix this
    restored x into inner['y'] and y into x without a peep."""
    x = jnp.arange(4.0)
    y = jnp.arange(4.0) + 10.0
    saved = {"x": x, "y": y}                # flat: two leaves
    target = {"a": jnp.zeros(4), "b": {"c": jnp.zeros(4)}}   # nested: two
    p = str(tmp_path / "ck.msgpack")
    save_checkpoint(p, saved, step=1)
    with pytest.raises(ValueError, match="treedef mismatch"):
        restore_checkpoint(p, target)
    # matching structure still restores fine
    out, _ = restore_checkpoint(p, {"x": jnp.zeros(4), "y": jnp.zeros(4)})
    np.testing.assert_array_equal(out["x"], x)
    np.testing.assert_array_equal(out["y"], y)


_TINY = ModelConfig(name="ck-tiny", family="dense", num_layers=2, d_model=32,
                    num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                    vocab_size=61, dtype="float32", rope_theta=10_000.0)


def _tc(steps):
    return TrainConfig(chunk_size=16, k_chunks=1, learning_rate=1e-3,
                       warmup_steps=2, total_steps=steps)


@pytest.mark.slow
def test_save_resume_matches_uninterrupted(tmp_path):
    """1 step + checkpoint, then --resume for 1 more == an uninterrupted
    2-step run (params AND optimizer state), incl. the replayed sampler."""
    kw = dict(batch_per_step=2, max_len=40, prefetch_depth=0, log_every=10)
    ck = str(tmp_path / "step1.msgpack")
    train(_TINY, _tc(1), checkpoint_path=ck, **kw)
    p_res, o_res, h_res = train(_TINY, _tc(2), resume_path=ck, **kw)
    p_ref, o_ref, h_ref = train(_TINY, _tc(2), **kw)
    assert len(h_res) == 1 and h_res[0]["step"] == 1
    # the resumed step must consume the same sampled batch as step 1 of the
    # uninterrupted run ...
    assert h_res[0]["n_chunks"] == h_ref[1]["n_chunks"]
    np.testing.assert_allclose(h_res[0]["loss"], h_ref[1]["loss"],
                               rtol=1e-6)
    # ... and land on the same trained state
    for got, want in ((p_res, p_ref), (o_res, o_ref)):
        import jax
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7),
            got, want)
