"""Algorithm 1 (chunk construction) unit + property tests."""
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.core.chunking import (construct_chunks, group_chunks,
                                 materialize_chunk)


def test_paper_figure4_example():
    """16 sequences, one long (split into 4), shorts packed into 3 chunks."""
    C = 8
    lengths = {6: 4 * C}                       # the long sequence
    rng = np.random.RandomState(0)
    short_total = 0
    for i in range(15):
        sid = i if i < 6 else i + 1
        lengths[sid] = int(rng.randint(1, C))
        short_total += lengths[sid]
    chunks = construct_chunks(lengths, C)
    groups, standalone = group_chunks(chunks)
    assert list(groups) == [6]
    assert len(groups[6]) == 4
    assert all(c.tokens_used == C for c in groups[6])
    lo = -(-short_total // C)
    assert len(standalone) >= lo               # minimal-ish bin count
    assert len(standalone) <= lo + 1


@given(st.lists(st.integers(1, 300), min_size=1, max_size=40),
       st.integers(4, 64))
@settings(max_examples=200, deadline=None)
def test_chunk_construction_properties(lens, chunk_size):
    lengths = {i: l for i, l in enumerate(lens)}
    chunks = construct_chunks(lengths, chunk_size)
    # no chunk exceeds ChunkSize
    assert all(c.tokens_used <= chunk_size for c in chunks)
    # every token of every sequence appears exactly once, in order
    seen = {i: [] for i in lengths}
    for c in chunks:
        for it in c.items:
            seen[it.seq_id].append((it.start, it.length))
    for sid, l in lengths.items():
        parts = sorted(seen[sid])
        assert parts[0][0] == 0
        assert sum(p[1] for p in parts) == l
        off = 0
        for s, ln in parts:
            assert s == off
            off += ln
    # dependent groups: ascending contiguous indexes, full chunks except last
    groups, standalone = group_chunks(chunks)
    for sid, g in groups.items():
        assert lengths[sid] > chunk_size
        assert [c.index_in_group for c in g] == list(range(len(g)))
        assert all(c.tokens_used == chunk_size for c in g[:-1])
    # bin count lower bound is respected within +1 (FFD guarantee style)
    short_total = sum(l for l in lens if l <= chunk_size)
    if short_total:
        lo = -(-short_total // chunk_size)
        assert len(standalone) >= lo


def test_materialize_labels_cross_chunk_boundary():
    """A dependent chunk's last token must be supervised by the next chunk's
    first token (no boundary loss dropped)."""
    seq = np.arange(100, 100 + 20, dtype=np.int32)
    chunks = construct_chunks({0: 20}, 8)
    groups, _ = group_chunks(chunks)
    mats = [materialize_chunk(c, {0: seq}) for c in groups[0]]
    assert mats[0]["labels"][0, 7] == seq[8]
    assert mats[0]["loss_mask"][0, 7] == 1.0
    assert mats[1]["labels"][0, 7] == seq[16]
    # final token of the sequence has no label
    assert mats[2]["loss_mask"][0, 3] == 0.0
    assert (mats[2]["segment_ids"][0, 4:] == 0).all()   # padding
    # positions are global within the sequence
    assert (mats[1]["positions"][0, :8] == np.arange(8, 16)).all()


def test_materialize_packed_standalone():
    seqs = {0: np.arange(5, dtype=np.int32), 1: np.arange(50, 53, dtype=np.int32)}
    chunks = construct_chunks({0: 5, 1: 3}, 16)
    assert len(chunks) == 1
    m = materialize_chunk(chunks[0], seqs)
    seg = m["segment_ids"][0]
    assert set(seg.tolist()) == {0, 1, 2}
    # per-segment positions restart
    for sid in (1, 2):
        idx = np.where(seg == sid)[0]
        assert (m["positions"][0, idx] == np.arange(len(idx))).all()
        # last token of each segment is not supervised
        assert m["loss_mask"][0, idx[-1]] == 0.0
