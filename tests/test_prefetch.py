"""Async host-side prefetch: ordering, overlap, error propagation, shutdown."""
import threading
import time

import pytest

from repro.data.prefetch import Prefetcher, synchronous


def test_yields_all_items_in_order():
    with Prefetcher(lambda step: step * step, 20, depth=2) as pf:
        assert list(pf) == [s * s for s in range(20)]


def test_matches_synchronous_stream():
    def produce(step):
        return ("batch", step, [step] * 3)
    assert (list(Prefetcher(produce, 7, depth=3))
            == list(synchronous(produce, 7)))


def test_runs_ahead_of_consumer():
    """With depth=2 the producer builds batches while the consumer 'computes'."""
    produced = []
    ran_ahead = threading.Event()

    def produce(step):
        produced.append(step)
        if step >= 2:                   # item 0 consumed + 2 queued = ahead
            ran_ahead.set()
        return step

    pf = Prefetcher(produce, 10, depth=2)
    first = next(pf)
    assert first == 0
    # while the consumer sits on item 0, the producer must reach item 2
    # without any further next() calls (item 0 handed over + depth-2 queue)
    assert ran_ahead.wait(timeout=5.0), f"producer stalled at {produced}"
    pf.close()


def test_producer_exception_surfaces_at_next():
    def produce(step):
        if step == 3:
            raise ValueError("boom at 3")
        return step

    pf = Prefetcher(produce, 10, depth=1)
    got = []
    with pytest.raises(ValueError, match="boom at 3"):
        for item in pf:
            got.append(item)
    assert got == [0, 1, 2]


def test_close_unblocks_producer_thread():
    pf = Prefetcher(lambda step: step, 1000, depth=1)
    assert next(pf) == 0
    pf.close()
    assert not pf._thread.is_alive()
    # closing twice is fine
    pf.close()


def test_zero_depth_escape_hatch_is_lazy():
    calls = []
    gen = synchronous(lambda s: calls.append(s) or s, 5)
    assert calls == []                  # nothing runs until consumed
    assert next(gen) == 0 and calls == [0]


def test_no_thread_leak():
    before = threading.active_count()
    for _ in range(5):
        with Prefetcher(lambda step: step, 3, depth=2) as pf:
            list(pf)
    time.sleep(0.1)
    assert threading.active_count() <= before + 1


# ----------------------------------------- failure paths (ring-overlap PR) --
def test_exception_behind_full_queue_propagates_without_hang():
    """The producer dies while the queue is already full of good items: the
    consumer must receive every item produced before the failure, then the
    exception — and the worker thread must exit (no orphan blocked on a
    full-queue put)."""
    def produce(step):
        if step == 2:
            raise RuntimeError("died at 2")
        return step

    pf = Prefetcher(produce, 10, depth=2)
    got = []
    with pytest.raises(RuntimeError, match="died at 2"):
        deadline = time.time() + 10.0
        for item in pf:
            got.append(item)
            assert time.time() < deadline, "consumer hung"
    assert got == [0, 1]
    pf._thread.join(timeout=5.0)
    assert not pf._thread.is_alive()


def test_early_consumer_exit_drains_and_joins():
    """Consumer takes one item from a long stream and bails: close() must
    unblock the producer (mid-put on a full queue), drain the buffer, and
    join the thread — the launch driver's finally-close path."""
    started = threading.Event()

    def produce(step):
        started.set()
        return ("big", step)

    pf = Prefetcher(produce, 10_000, depth=1)
    assert started.wait(timeout=5.0)
    assert next(pf) == ("big", 0)
    pf.close()                        # early exit: 9999 items never consumed
    assert not pf._thread.is_alive()
    # the stream is dead after close — no stale buffered items leak out
    pf.close()                        # idempotent


def test_depth_one_and_two_streams_identical():
    """Prefetch depth changes overlap, never content or order — the same
    guarantee the offloaded StateStore's bucket prefetch relies on."""
    def produce(step):
        return (step, step * 7 % 13)

    one = list(Prefetcher(produce, 25, depth=1))
    two = list(Prefetcher(produce, 25, depth=2))
    sync = list(synchronous(produce, 25))
    assert one == two == sync
