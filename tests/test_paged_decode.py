"""Paged decode-attention path: page-table gather + per-request cache_len
vs the dense sdpa reference (GQA, sliding-window, softcap) — interpret mode
so it runs in CI, same as the other Pallas kernel suites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention import paged_decode_attention
from repro.kernels.ref import decode_attention_ref, paged_decode_attention_ref
from repro.models import api, decode

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def scatter_pages(k, tables, page_size, n_pages, dtype=None):
    """Scatter a dense (B, Hkv, S, D) cache into a (n_pages, page_size, Hkv,
    D) pool laid out by ``tables`` (B, n_pages_per_req)."""
    B, Hkv, S, D = k.shape
    pool = np.zeros((n_pages, page_size, Hkv, D),
                    dtype or np.asarray(k).dtype)
    kn = np.asarray(k)
    for b in range(B):
        for t in range(S):
            pg = int(tables[b, t // page_size])
            pool[pg, t % page_size] = kn[b, :, t]
    return jnp.asarray(pool)


def make_case(key, B, Hq, Hkv, D, page_size, n_req_pages, dtype=jnp.float32):
    """Random q + a paged pool whose gather reproduces a dense cache."""
    S = n_req_pages * page_size
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32).astype(dtype)
    # non-trivial page layout: request pages interleaved, never page 0
    n_pages = 1 + B * n_req_pages
    perm = 1 + np.random.RandomState(0).permutation(B * n_req_pages)
    tables = perm.reshape(B, n_req_pages).astype(np.int32)
    k_pages = scatter_pages(k, tables, page_size, n_pages)
    v_pages = scatter_pages(v, tables, page_size, n_pages)
    return q, k, v, k_pages, v_pages, jnp.asarray(tables)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,D,ps,npg,lens,window,softcap", [
    (2, 4, 2, 64, 16, 4, (40, 17), 0, 0.0),      # GQA, ragged lengths
    (1, 8, 8, 128, 32, 2, (63,), 0, 0.0),        # MHA, big pages
    (2, 4, 1, 64, 16, 4, (50, 9), 24, 0.0),      # sliding window
    (2, 4, 2, 64, 16, 4, (40, 33), 0, 30.0),     # softcap
    (2, 4, 2, 64, 16, 4, (55, 12), 16, 50.0),    # window + softcap
])
def test_paged_kernel_matches_oracle(dtype, B, Hq, Hkv, D, ps, npg, lens,
                                     window, softcap):
    q, k, v, kp, vp, tbl = make_case(jax.random.PRNGKey(0), B, Hq, Hkv, D,
                                     ps, npg, dtype)
    cache_lens = jnp.asarray(lens, jnp.int32)
    out = paged_decode_attention(q, kp, vp, tbl, cache_lens, window=window,
                                 softcap=softcap, interpret=True)
    expect = paged_decode_attention_ref(q, kp, vp, tbl, cache_lens,
                                        window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


def test_paged_kernel_matches_dense_reference_per_row():
    """Each batch row must equal the *dense* decode reference run at that
    row's own cache_len — per-request lengths, not a shared scalar."""
    B, Hq, Hkv, D, ps, npg = 3, 4, 2, 64, 16, 4
    lens = (12, 40, 63)
    q, k, v, kp, vp, tbl = make_case(jax.random.PRNGKey(1), B, Hq, Hkv, D,
                                     ps, npg)
    out = paged_decode_attention(q, kp, vp, tbl, jnp.asarray(lens, jnp.int32),
                                 interpret=True)
    for b, clen in enumerate(lens):
        expect = decode_attention_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                      clen)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(expect), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [24, 0])
def test_paged_kernel_traced_window(window):
    """window may be a traced scalar (local/global alternation shares one
    compile inside a layer scan); a traced *zero* means global, exactly like
    the static 0."""
    q, k, v, kp, vp, tbl = make_case(jax.random.PRNGKey(2), 2, 4, 2, 64, 16, 4)
    lens = jnp.asarray((40, 17), jnp.int32)
    out = jax.jit(
        lambda w: paged_decode_attention(q, kp, vp, tbl, lens, window=w,
                                         interpret=True))(jnp.int32(window))
    expect = paged_decode_attention_ref(q, kp, vp, tbl, lens, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------- model-level paged step ---
def tiny(**kw):
    base = dict(name="tiny-paged", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=97, dtype="float32", rope_theta=10_000.0)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("variant", ["plain", "window", "pallas_interpret"])
def test_decode_step_paged_matches_dense_decode_step(variant):
    """decode_step_paged through a paged pool == decode_step through the
    dense cache, greedy-decoding several tokens."""
    kw = {}
    if variant == "window":
        kw = dict(sliding_window=24, local_global_alternate=True,
                  attn_softcap=50.0)
    if variant == "pallas_interpret":
        kw = dict(attn_backend="pallas_interpret")
    cfg = tiny(**kw)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    B, T, G, ps, maxp = 2, 24, 6, 8, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 1,
                              cfg.vocab_size)
    logits, state, _ = api.forward(cfg, params, {"tokens": toks})

    from repro.launch.serve import state_to_cache
    dense_cache, _ = state_to_cache(cfg, params, state, T + G + 1, B)

    pool = decode.init_paged_cache(cfg, pages_total=1 + B * maxp,
                                   page_size=ps)
    tbl = np.stack([1 + b * maxp + np.arange(maxp) for b in range(B)]
                   ).astype(np.int32)
    kp, vp = np.array(pool["k"]), np.array(pool["v"])
    kd, vd = np.asarray(state["k"]), np.asarray(state["v"])
    for b in range(B):
        for t in range(T):
            pg = tbl[b, t // ps]
            kp[:, pg, t % ps] = kd[:, b, t]
            vp[:, pg, t % ps] = vd[:, b, t]
    cache = {"k": jnp.asarray(kp), "v": jnp.asarray(vp)}
    tbl = jnp.asarray(tbl)

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    lens = jnp.full((B,), T, jnp.int32)
    for i in range(G):
        ld, dense_cache = decode.decode_step(cfg, params, dense_cache, tok,
                                             T + i)
        lp, cache = decode.decode_step_paged(cfg, params, cache, tok, lens,
                                             tbl)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(ld),
                                   rtol=3e-4, atol=3e-4)
        tok = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
        lens = lens + 1


def test_paged_cache_rejects_non_attention_families():
    from repro.configs.registry import ARCHS
    cfg = ARCHS["mamba2-130m"].reduced()
    with pytest.raises(NotImplementedError, match="init_decode_cache"):
        decode.init_paged_cache(cfg, 8, 16)
