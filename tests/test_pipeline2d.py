"""2D (data x pipe) K-retention pipeline executor: numerical equivalence to
the single-device ChunkFlow scheduler, and exact agreement of its schedule
accounting with core.schedule_sim.simulate_rotation.

Both tests run in subprocesses because XLA_FLAGS must be set before jax
initializes (and the rest of the suite must keep seeing 1 device), like
test_pipeline_exec.py / test_dp_balance.py.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import chunking, chunked_step
from repro.models import api
from repro.launch import mesh as mesh_lib

cfg = ModelConfig(name="tiny2d", family="dense", num_layers=4, d_model=32,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=61, dtype="float32", rope_theta=10_000.0)
C = 16


def make_batch(lengths, seed=0):
    rng = np.random.RandomState(seed)
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in lengths.items()}
    chunks = chunking.construct_chunks(lengths, C)
    groups, standalone = chunking.group_chunks(chunks)
    gb = [[chunking.materialize_chunk(c, seqs) for c in g]
          for g in groups.values()]
    sb = [chunking.materialize_chunk(c, seqs) for c in standalone]
    return gb, sb


def single_device_ref(gb, sb, k):
    gb_d = [[{k2: jnp.asarray(v) for k2, v in b.items()} for b in g]
            for g in gb]
    sb_d = [{k2: jnp.asarray(v) for k2, v in b.items()} for b in sb]
    return chunked_step.run_batch(cfg, params, gb_d, sb_d, k=k)
"""

EQUIVALENCE = _PRELUDE + r"""
params = api.init_params(cfg, jax.random.PRNGKey(0))
mesh = mesh_lib.make_train_mesh(2, 2)          # data=2 x pipe=2

# mixed-length stream: a 5-chunk group (recompute with K=2), a 3-chunk
# group, and short sequences that pack into standalone chunks
gb, sb = make_batch({0: 5 * C - 3, 1: 3 * C, 2: 9, 3: 5, 4: 12, 5: 7})

for k in (2, 1):                               # K < N: recompute exercised
    loss, grads, stats = chunked_step.run_batch(cfg, params, gb, sb, k=k,
                                                mesh=mesh)
    ref_loss, ref_grads, _ = single_device_ref(gb, sb, k)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        grads, ref_grads)
    assert stats.recompute_calls > 0           # K < N actually recomputed
    assert stats.max_live_residuals <= max(1, k)

# dense-only stream (one long group, no standalone), K covering everything
gb, sb = make_batch({0: 4 * C}, seed=3)
loss, grads, stats = chunked_step.run_batch(cfg, params, gb, sb, k=4,
                                            mesh=mesh)
ref_loss, ref_grads, _ = single_device_ref(gb, sb, 4)
np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
jax.tree.map(
    lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
    grads, ref_grads)
assert stats.recompute_calls == 0
print("PIPELINE2D-EQUIVALENCE-OK")
"""

SIM_AGREEMENT = _PRELUDE + r"""
from repro.core.schedule_sim import simulate_rotation
from repro.distributed import pipeline

params = api.init_params(cfg, jax.random.PRNGKey(1))

MIXES = {
    "uniform": {0: 4 * C, 1: 4 * C},
    "longtail": {0: 6 * C - 5, 1: 2 * C, 2: 9, 3: 30, 4: 12},
}
kv_bytes_per_slot = (2 * cfg.num_layers * C * cfg.padded_num_kv_heads
                     * cfg.resolved_head_dim * 4)     # k+v, fp32

for stages in (2, 4):
    mesh = mesh_lib.make_train_mesh(1, stages)
    for mix, lengths in MIXES.items():
        gb, sb = make_batch(lengths, seed=7)
        for k in (1, 2, 4):
            loss, grads, st = chunked_step.run_batch(cfg, params, gb, sb,
                                                     k=k, mesh=mesh)
            sim = simulate_rotation(st.wave_sizes, stages, k)
            tag = (stages, mix, k)
            assert st.recompute_calls == sim.recompute_count, tag
            assert st.max_live_residuals == sim.peak_resident_chunks, tag
            assert st.kv_capacity_slots == sim.kv_capacity_slots, tag
            assert st.makespan_units == sim.makespan, tag
            assert st.useful_units == sim.useful_time, tag
            assert st.recompute_units == sim.recompute_time, tag
            assert abs(st.bubble_ratio - sim.bubble_ratio) < 1e-12, tag
            # resident-state bytes: executor's measured StateStore == the
            # simulator's slot prediction converted with the model geometry
            want = max(sim.kv_capacity_slots) * kv_bytes_per_slot
            assert st.kv_store_bytes == want, (tag, st.kv_store_bytes, want)
print("PIPELINE2D-SIM-AGREEMENT-OK")
"""


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True,
                          cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))


def test_pipeline2d_matches_single_device():
    r = _run(EQUIVALENCE)
    assert "PIPELINE2D-EQUIVALENCE-OK" in r.stdout, r.stdout + "\n" + r.stderr


def test_pipeline2d_matches_schedule_sim():
    r = _run(SIM_AGREEMENT)
    assert "PIPELINE2D-SIM-AGREEMENT-OK" in r.stdout, \
        r.stdout + "\n" + r.stderr
