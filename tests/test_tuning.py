"""Paper §5 grid search behaviour — and the tuner/executor agreement fix:
PP candidates are scored with the rotation schedule the PR-4 executor runs
(`simulate_rotation`), not Megatron-style `simulate_1f1b`."""
import dataclasses

import numpy as np

from repro.core.chunking import construct_chunks
from repro.core.schedule_sim import (chunks_to_microbatches, simulate_1f1b,
                                     simulate_rotation)
from repro.core.tuning import grid_search, rotation_wave_sizes, seq_time
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF


def _batches(n=4, batch=64, max_len=262_144, seed=0):
    s = LongTailSampler(PAPER_EVAL_CDF, min_len=32, seed=seed,
                        max_len=max_len)
    return [dict(enumerate(s.sample_batch_lengths(batch))) for _ in range(n)]


def test_no_pp_rule_k1_max_chunksize():
    """Without PP: K=1 and the largest ChunkSize within memory (paper §5)."""
    r = grid_search(_batches(), pp=1, memory_token_budget=32_768)
    assert r.k == 1
    assert r.chunk_size == 32_768     # biggest allowed always wins w/o PP
    r2 = grid_search(_batches(), pp=1, memory_token_budget=8_192)
    assert r2.chunk_size == 8_192     # memory bound respected


def test_pp_prefers_interior_point():
    """With PP=4 and the paper's memory budget, the best config is interior
    (neither min-chunk nor the single-biggest-chunk corner) — Table 6."""
    r = grid_search(_batches(), pp=4, memory_token_budget=32_768)
    assert (r.chunk_size, r.k) in r.table
    # the extremes of Table 6 must not win
    worst_small = r.table.get((2048, 16))
    worst_big = r.table.get((32_768, 1))
    assert r.score <= worst_small and r.score <= worst_big
    assert 2048 <= r.chunk_size <= 32_768
    # memory budget honored
    assert r.chunk_size * r.k <= 32_768


def test_scores_deterministic():
    b = _batches(n=2)
    r1 = grid_search(b, pp=4, memory_token_budget=16_384)
    r2 = grid_search(b, pp=4, memory_token_budget=16_384)
    assert r1.table == r2.table


def test_pp_scores_pinned_to_rotation_sim():
    """grid_search(pp>1) scores are exactly simulate_rotation makespans —
    the closed form the executor reports in PipelineStats.makespan_units —
    at unit = seq_time(ChunkSize), for every grid candidate."""
    batches = _batches(n=2, batch=32)
    pp = 4
    r = grid_search(batches, pp=pp, memory_token_budget=32_768)
    for (cs, k), score in r.table.items():
        want = sum(
            simulate_rotation(rotation_wave_sizes(construct_chunks(ls, cs)),
                              pp, k, unit=seq_time(cs)).makespan
            for ls in batches) / len(batches)
        assert score == want, (cs, k, score, want)


def _score_1f1b(batches, pp, budget, chunk_sizes, ks):
    """The pre-fix scorer (1F1B with variable-duration microbatches)."""
    table = {}
    for cs in chunk_sizes:
        for k in ks:
            if k * cs > budget:
                continue
            total = 0.0
            for lengths in batches:
                mbs = chunks_to_microbatches(construct_chunks(lengths, cs),
                                             k=k)
                mbs = [dataclasses.replace(m, fwd=seq_time(m.fwd))
                       for m in mbs]
                total += simulate_1f1b(mbs, pp, state_aware=True).makespan
            table[(cs, k)] = total / len(batches)
    return table


def test_1f1b_scoring_ranking_bug_fixed():
    """The old 1F1B scorer ranks candidates differently from the rotation
    schedule the executor actually runs (short chunks cost less than a tick
    under 1F1B; the rotation executes every capacity-padded slot as one
    uniform tick). On the paper's own length distribution the two scorers
    disagree on the best ChunkSize — grid_search must return the rotation
    argmin, not the 1F1B one."""
    batches = _batches(n=4, batch=64)
    grid = dict(chunk_sizes=(2048, 4096, 8192, 16384, 32768),
                ks=(1, 2, 4, 8, 16))
    r = grid_search(batches, pp=4, memory_token_budget=32_768, **grid)
    old = _score_1f1b(batches, 4, 32_768, **grid)
    old_best = min(old, key=old.get)
    new_best = min(r.table, key=r.table.get)
    assert old_best != new_best, \
        "scorers agree on this grid; the regression case is gone"
    assert (r.chunk_size, r.k) == new_best
    # and the 1F1B pick is measurably worse in executor (rotation) units
    assert r.table[old_best] > r.score
