"""Paper §5 grid search behaviour."""
import numpy as np

from repro.core.tuning import grid_search
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF


def _batches(n=4, batch=64, max_len=262_144, seed=0):
    s = LongTailSampler(PAPER_EVAL_CDF, min_len=32, seed=seed,
                        max_len=max_len)
    return [dict(enumerate(s.sample_batch_lengths(batch))) for _ in range(n)]


def test_no_pp_rule_k1_max_chunksize():
    """Without PP: K=1 and the largest ChunkSize within memory (paper §5)."""
    r = grid_search(_batches(), pp=1, memory_token_budget=32_768)
    assert r.k == 1
    assert r.chunk_size == 32_768     # biggest allowed always wins w/o PP
    r2 = grid_search(_batches(), pp=1, memory_token_budget=8_192)
    assert r2.chunk_size == 8_192     # memory bound respected


def test_pp_prefers_interior_point():
    """With PP=4 and the paper's memory budget, the best config is interior
    (neither min-chunk nor the single-biggest-chunk corner) — Table 6."""
    r = grid_search(_batches(), pp=4, memory_token_budget=32_768)
    assert (r.chunk_size, r.k) in r.table
    # the extremes of Table 6 must not win
    worst_small = r.table.get((2048, 16))
    worst_big = r.table.get((32_768, 1))
    assert r.score <= worst_small and r.score <= worst_big
    assert 2048 <= r.chunk_size <= 32_768
    # memory budget honored
    assert r.chunk_size * r.k <= 32_768


def test_scores_deterministic():
    b = _batches(n=2)
    r1 = grid_search(b, pp=4, memory_token_budget=16_384)
    r2 = grid_search(b, pp=4, memory_token_budget=16_384)
    assert r1.table == r2.table
