"""Continuous-batching engine acceptance: token-exact vs the static-batch
path, single compile, bounded KV memory, scheduler/allocator invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch.serve import generate
from repro.models import api
from repro.serving import (Engine, EngineConfig, PagePool, Request,
                           TRACE_EVENTS, poisson_requests, reset_trace_log,
                           trace_requests)


def tiny(**kw):
    base = dict(name="tiny-engine", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=97, dtype="float32", rope_theta=10_000.0)
    base.update(kw)
    return ModelConfig(**base)


def reference_tokens(cfg, params, prompts, gen_len, chunk):
    """Static-batch serve.py path, one request at a time (ragged lengths)."""
    return [np.asarray(generate(cfg, params, jnp.asarray(p)[None],
                                gen_len=gen_len, chunk_size=chunk))[0]
            for p in prompts]


def make_trace(lengths, gen_len, vocab, seed=0, arrivals=None):
    reqs = trace_requests(lengths, vocab_size=vocab, max_new_tokens=gen_len,
                          arrival_times=arrivals, seed=seed)
    return reqs, [r.prompt for r in reqs]


# ------------------------------------------------------------ acceptance ----
def test_engine_matches_static_batch_exactly_and_compiles_once():
    """For a fixed trace the engine's greedy tokens == static-batch serve.py,
    while the engine step traces exactly once and peak KV memory is the pool
    allocation — independent of the longest prompt."""
    cfg = tiny()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    C = 16
    lengths = [40, 56, 24, 48, 33]
    gen = 8
    reqs, prompts = make_trace(lengths, gen, cfg.vocab_size)
    ref = reference_tokens(cfg, params, prompts, gen, C)

    ecfg = EngineConfig(page_size=8, pages_total=48, max_running=3,
                        prefill_chunk=C, prefill_slots=1, max_pages_per_req=8)
    eng = Engine(cfg, params, ecfg)
    reset_trace_log()
    results = eng.run(reqs)
    assert len(TRACE_EVENTS) == 1, TRACE_EVENTS   # ONE compile for all ticks

    results.sort(key=lambda r: r.req_id)
    for i, r in enumerate(results):
        assert r.done
        np.testing.assert_array_equal(np.asarray(r.tokens), ref[i])

    # peak KV memory == the fixed pool: pages_total * page_size slots
    hd = cfg.resolved_head_dim
    expect = (2 * cfg.num_layers * ecfg.pages_total * ecfg.page_size
              * cfg.padded_num_kv_heads * hd
              * jnp.dtype(cfg.dtype).itemsize)
    assert eng.kv_pool_bytes == expect
    assert eng.pool.peak_in_use <= ecfg.pages_total - 1


def test_engine_kv_memory_independent_of_longest_prompt():
    """Same EngineConfig, traces whose longest prompt differs 2x: identical
    pool bytes (a dense (B, max_seq) cache would scale with the tail)."""
    cfg = tiny()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, pages_total=40, max_running=2,
                        prefill_chunk=16, prefill_slots=1,
                        max_pages_per_req=16)
    pool_bytes = []
    for lengths in ([24, 32], [120, 16]):
        eng = Engine(cfg, params, ecfg)
        reqs, _ = make_trace(lengths, 4, cfg.vocab_size)
        results = eng.run(reqs)
        assert all(r.done for r in results)
        pool_bytes.append(eng.kv_pool_bytes)
    assert pool_bytes[0] == pool_bytes[1]


def test_engine_preemption_resumes_exactly():
    """A pool too small for all admitted requests forces preemption; the
    resume-by-recompute path must regenerate identical greedy tokens."""
    cfg = tiny()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    C, gen = 16, 10
    lengths = [40, 56, 24, 48]
    reqs, prompts = make_trace(lengths, gen, cfg.vocab_size,
                               arrivals=[0.0, 1.0, 3.0, 5.0])
    ref = reference_tokens(cfg, params, prompts, gen, C)
    ecfg = EngineConfig(page_size=8, pages_total=20, max_running=3,
                        prefill_chunk=C, prefill_slots=1,
                        max_pages_per_req=10)
    eng = Engine(cfg, params, ecfg)
    results = sorted(eng.run(reqs), key=lambda r: r.req_id)
    assert eng.sched.n_preemptions >= 1       # the tight pool actually bit
    for i, r in enumerate(results):
        np.testing.assert_array_equal(np.asarray(r.tokens), ref[i])
        if r.n_preemptions:
            assert len(r.tokens) == gen       # no duplicated emissions


def test_engine_streaming_callbacks_and_timestamps():
    cfg = tiny()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    seen = []
    reqs, prompts = make_trace([24, 40], 5, cfg.vocab_size)
    for r in reqs:
        r.on_token = lambda rid, tok: seen.append((rid, tok))
    eng = Engine(cfg, params, EngineConfig(
        page_size=8, pages_total=32, max_running=2, prefill_chunk=8,
        prefill_slots=1, max_pages_per_req=8))
    results = sorted(eng.run(reqs), key=lambda r: r.req_id)
    ref = reference_tokens(cfg, params, prompts, 5, 8)
    # streaming saw every token, in order, tagged with the right request
    for i, r in enumerate(results):
        streamed = [t for rid, t in seen if rid == r.req_id]
        np.testing.assert_array_equal(streamed, ref[i])
        assert r.t_admitted <= r.t_first_token <= r.t_finish
        assert r.ttft >= 0 and r.e2e_latency >= r.ttft


def test_engine_mixed_vs_prefill_stall_same_tokens():
    """mixed=False (prefill stalls decode — the static-batching baseline)
    must still be token-exact; it just takes more ticks under load."""
    cfg = tiny()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    reqs, prompts = make_trace([40, 40, 40], 6, cfg.vocab_size,
                               arrivals=[0.0, 2.0, 4.0])
    ref = reference_tokens(cfg, params, prompts, 6, 16)
    base = EngineConfig(page_size=8, pages_total=40, max_running=3,
                        prefill_chunk=16, prefill_slots=1,
                        max_pages_per_req=8)
    for mixed in (True, False):
        eng = Engine(cfg, params, dataclasses.replace(base, mixed=mixed))
        reqs_i, _ = make_trace([40, 40, 40], 6, cfg.vocab_size,
                               arrivals=[0.0, 2.0, 4.0])
        results = sorted(eng.run(reqs_i), key=lambda r: r.req_id)
        for i, r in enumerate(results):
            np.testing.assert_array_equal(np.asarray(r.tokens), ref[i])


@pytest.mark.parametrize("variant", ["moe", "window_softcap"])
def test_engine_model_variants(variant):
    """Trace equivalence holds for MoE (uniform chunk capacity) and for
    gemma2-style sliding-window local/global alternation + softcap."""
    if variant == "moe":
        cfg = tiny(family="moe", num_experts=4, experts_per_token=2)
    else:
        cfg = tiny(sliding_window=24, local_global_alternate=True,
                   attn_softcap=50.0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    reqs, prompts = make_trace([24, 33, 48], 4, cfg.vocab_size)
    ref = reference_tokens(cfg, params, prompts, 4, 16)
    eng = Engine(cfg, params, EngineConfig(
        page_size=8, pages_total=40, max_running=2, prefill_chunk=16,
        prefill_slots=1, max_pages_per_req=8))
    results = sorted(eng.run(reqs), key=lambda r: r.req_id)
    for i, r in enumerate(results):
        np.testing.assert_array_equal(np.asarray(r.tokens), ref[i])


def test_engine_rejects_non_attention_families():
    from repro.configs.registry import ARCHS
    cfg = ARCHS["mamba2-130m"].reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        Engine(cfg, params, EngineConfig())


def test_engine_rejects_oversized_request():
    cfg = tiny()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(
        page_size=8, pages_total=16, max_running=1, prefill_chunk=8,
        prefill_slots=1, max_pages_per_req=4))    # max_model_len = 32
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(req_id=0, prompt=np.ones(40, np.int32),
                           max_new_tokens=4))


# ---------------------------------------------------- scheduler/allocator ---
def test_page_pool_invariants():
    pool = PagePool(8)                # 7 usable pages, page 0 reserved
    a = pool.alloc(3)
    b = pool.alloc(4)
    assert a is not None and b is not None
    assert 0 not in a + b             # null page never handed out
    assert len(set(a + b)) == 7       # no double allocation
    assert pool.alloc(1) is None      # exhausted -> all-or-nothing None
    pool.free(a)
    assert pool.free_pages == 3
    c = pool.alloc(3)
    assert sorted(c) == sorted(a)
    with pytest.raises(AssertionError):
        pool.free([99])               # foreign page


def test_scheduler_fcfs_admission_blocks_behind_head():
    """Strict FCFS: a small request behind a too-big head must wait."""
    from repro.serving.scheduler import Scheduler
    ecfg = EngineConfig(page_size=8, pages_total=9, max_running=2,
                        prefill_chunk=8, prefill_slots=1, max_pages_per_req=8)
    pool = PagePool(ecfg.pages_total)
    sched = Scheduler(ecfg, pool)
    # head needs 6 pages padded; second needs 2
    sched.submit(Request(req_id=0, prompt=np.ones(40, np.int32),
                         max_new_tokens=8), now=0.0)
    sched.submit(Request(req_id=1, prompt=np.ones(8, np.int32),
                         max_new_tokens=8), now=0.0)
    pool.alloc(4)                     # shrink the pool below the head's need
    assert sched.admit(0.0) == 0      # head can't fit -> nobody admits
    assert len(sched.waiting) == 2


def test_scheduler_work_budget_limits_prefill():
    """With a tick budget only big enough for decode + one chunk, the packer
    schedules at most one prefill chunk even when two slots are configured."""
    from repro.core.dp_balance import chunk_token_work
    from repro.serving.scheduler import Scheduler
    C = 16
    budget = chunk_token_work(C, 0) * 1.5
    ecfg = EngineConfig(page_size=8, pages_total=64, max_running=4,
                        prefill_chunk=C, prefill_slots=2,
                        max_pages_per_req=8, tick_work_budget=budget)
    pool = PagePool(ecfg.pages_total)
    sched = Scheduler(ecfg, pool)
    for i in range(3):
        sched.submit(Request(req_id=i, prompt=np.ones(32, np.int32),
                             max_new_tokens=4), now=0.0)
    sched.admit(0.0)
    plan = sched.plan_tick(0.0)
    assert len(plan.prefill) == 1     # budget, not slot count, is binding
    # FCFS: the chunk belongs to the oldest admitted request
    assert plan.prefill[0][0].req.req_id == 0


def test_scheduler_decode_growth_skips_preempted_slots():
    """An older slot's decode-page growth may preempt a younger slot that is
    still in the decode iteration list; the orphaned slot must be skipped —
    no spurious second preemption, no leaked pages."""
    from repro.serving.scheduler import Scheduler
    ecfg = EngineConfig(page_size=8, pages_total=3, max_running=2,
                        prefill_chunk=8, prefill_slots=2, max_pages_per_req=3)
    pool = PagePool(ecfg.pages_total)
    sched = Scheduler(ecfg, pool)
    for i in range(2):
        sched.submit(Request(req_id=i, prompt=np.ones(8, np.int32),
                             max_new_tokens=8), now=0.0)
    assert sched.admit(0.0) == 2          # one page each; pool now dry
    plan = sched.plan_tick(0.0)
    assert len(plan.prefill) == 2
    for s, start, n in plan.prefill:      # single-chunk prompts -> decode
        sched.commit_prefill(s, start, n, next_token=1, now=0.0)
    old, young = sorted(sched.slots, key=lambda s: s.admit_seq)
    assert old.phase == young.phase == "decode"
    plan = sched.plan_tick(1.0)
    # old grows into the page freed by preempting young; orphaned young is
    # skipped instead of preempting old back on behalf of a dead slot
    assert plan.decode == [old]
    assert sched.n_preemptions == 1
    assert sched.slots[old.slot] is old
    assert len(sched.waiting) == 1 and sched.waiting[0].req is young.req
    assert pool.in_use == len(old.pages)  # no page attached to a dead slot
    assert pool.free_pages + pool.in_use == ecfg.pages_total - 1


def test_poisson_requests_long_tail():
    reqs = poisson_requests(64, rate=2.0, vocab_size=97, seed=3,
                            max_new_tokens=4, max_prompt=512)
    arr = [r.arrival_time for r in reqs]
    assert all(a < b for a, b in zip(arr, arr[1:]))
    assert all(16 <= r.prompt_len <= 512 for r in reqs)
