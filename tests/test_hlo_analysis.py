"""Loop-aware HLO analyzer: exact trip-count recovery on known programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_trip_count_exact():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    x = jnp.zeros((64, 64))
    w = jnp.zeros((64, 64))
    r = analyze(_compile_text(f, x, w))
    assert abs(r["flops"] / (2 * 64 ** 3 * 7) - 1) < 0.05


def test_nested_scan_multiplies():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    x = jnp.zeros((64, 64))
    w = jnp.zeros((64, 64))
    r = analyze(_compile_text(g, x, w))
    assert abs(r["flops"] / (2 * 64 ** 3 * 15) - 1) < 0.05


def test_grad_of_scan_triples_flops():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    x = jnp.zeros((64, 64))
    w = jnp.zeros((64, 64))
    r = analyze(_compile_text(jax.grad(f, argnums=1), x, w))
    assert abs(r["flops"] / (3 * 2 * 64 ** 3 * 7) - 1) < 0.05


def test_gqa_einsum_flops():
    """Batched einsum with contraction (GQA attention style)."""
    def f(q, k):
        return jnp.einsum("bqhgd,bkhd->bhgqk", q, k).sum()

    q = jnp.zeros((2, 32, 4, 2, 16))
    k = jnp.zeros((2, 48, 4, 16))
    r = analyze(_compile_text(f, q, k))
    expect = 2 * 2 * 4 * 2 * 32 * 48 * 16
    assert abs(r["flops"] / expect - 1) < 0.05


def test_tuple_typed_ops_parse():
    """HLO lines with tuple types containing /*index=N*/ comments parse."""
    def f(x):
        def body(carry, _):
            a, b, c, d, e, ff = carry
            return (a @ ff, b + 1, c, d, e, ff), None
        init = (x, x, x, x, x, x)
        (a, *_), _ = jax.lax.scan(body, init, None, length=4)
        return a.sum()

    x = jnp.zeros((32, 32))
    r = analyze(_compile_text(f, x))
    assert abs(r["flops"] / (2 * 32 ** 3 * 4) - 1) < 0.05
