"""Serving-path consistency: chunked prefill + decode == one-shot forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS
from repro.launch.serve import chunked_prefill, generate, state_to_cache
from repro.models import api, decode


def tiny_dense(**kw):
    base = dict(name="tiny", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=97, dtype="float32", rope_theta=10_000.0)
    base.update(kw)
    return ModelConfig(**base)


def test_chunked_prefill_matches_full_forward():
    cfg = tiny_dense()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 96), 1,
                              cfg.vocab_size)
    last, state = chunked_prefill(cfg, params, toks, chunk_size=32)
    full_logits, full_state, _ = api.forward(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(state["k"]),
                               np.asarray(full_state["k"]), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("variant", ["plain", "window"])
def test_decode_matches_teacher_forcing(variant):
    """Prefill T tokens then decode 8 more greedily; logits at each decode
    position must equal the full-forward logits over the grown sequence."""
    kw = {}
    if variant == "window":
        kw = dict(sliding_window=24, local_global_alternate=True,
                  attn_softcap=50.0)
    cfg = tiny_dense(**kw)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    T, G = 32, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, T), 1,
                              cfg.vocab_size)
    # decode path
    last, state = chunked_prefill(cfg, params, toks, chunk_size=T)
    cache, _ = state_to_cache(cfg, params, state, T + G + 1, 1)
    seq = [int(jnp.argmax(last[0]))]
    step = jax.jit(lambda p, c, t, l: decode.decode_step(cfg, p, c, t, l))
    cur = jnp.asarray([[seq[-1]]], jnp.int32)
    pos = T
    decode_logits = []
    for _ in range(G):
        logits, cache = step(params, cache, cur, pos)
        decode_logits.append(logits[0, 0])
        seq.append(int(jnp.argmax(logits[0, 0])))
        cur = jnp.asarray([[seq[-1]]], jnp.int32)
        pos += 1
    # teacher forcing reference
    grown = jnp.concatenate([toks, jnp.asarray(seq[:G], jnp.int32)[None]], 1)
    ref_logits, _, _ = api.forward(cfg, params, {"tokens": grown})
    for i in range(G):
        np.testing.assert_allclose(np.asarray(decode_logits[i]),
                                   np.asarray(ref_logits[0, T + i]),
                                   rtol=5e-4, atol=5e-4)


def test_ssm_decode_matches_teacher_forcing():
    cfg = ARCHS["mamba2-130m"].reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    T, G = 32, 6
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, T), 1,
                              cfg.vocab_size)
    logits, state, _ = api.forward(cfg, params, {"tokens": toks})
    cache = state          # ssm state IS the decode cache
    seq = [int(jnp.argmax(logits[0, -1]))]
    cur = jnp.asarray([[seq[-1]]], jnp.int32)
    outs = []
    for i in range(G):
        lg, cache = decode.decode_step(cfg, params, cache, cur, T + i)
        outs.append(lg[0, 0])
        seq.append(int(jnp.argmax(lg[0, 0])))
        cur = jnp.asarray([[seq[-1]]], jnp.int32)
    grown = jnp.concatenate([toks, jnp.asarray(seq[:G], jnp.int32)[None]], 1)
    ref, _, _ = api.forward(cfg, params, {"tokens": grown})
    for i in range(G):
        np.testing.assert_allclose(np.asarray(outs[i]),
                                   np.asarray(ref[0, T + i]),
                                   rtol=2e-3, atol=2e-3)


def test_generate_end_to_end():
    cfg = tiny_dense()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(4), (3, 40), 1,
                                 cfg.vocab_size)
    toks = generate(cfg, params, prompts, gen_len=8, chunk_size=16)
    assert toks.shape == (3, 8)
    a = np.asarray(toks)
    assert (a >= 0).all() and (a < cfg.vocab_size).all()


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_state_to_cache_dense_moe_conversion(family):
    """The prefill state lands verbatim in the decode cache's first P slots;
    the rest stays zero."""
    kw = dict(num_experts=4, experts_per_token=2) if family == "moe" else {}
    cfg = tiny_dense(family=family, **kw)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 24), 1,
                              cfg.vocab_size)
    _, state = chunked_prefill(cfg, params, toks, chunk_size=16)
    max_seq = 40
    cache, P = state_to_cache(cfg, params, state, max_seq, 2)
    assert P == 24
    assert cache["k"].shape[2] == max_seq
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache[leaf][:, :, :P]),
                                      np.asarray(state[leaf]))
        assert not np.asarray(cache[leaf][:, :, P:]).any()


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "whisper-small"])
def test_state_to_cache_rejects_hybrid_audio_families(arch):
    """hybrid/audio states don't map onto the dense KV cache — a loud
    NotImplementedError pointing at decode.init_decode_cache, not a silent
    wrong conversion."""
    cfg = ARCHS[arch].reduced()
    with pytest.raises(NotImplementedError, match="init_decode_cache"):
        state_to_cache(cfg, None, {}, 16, 1)


def test_state_to_cache_ssm_passthrough():
    """The ssm recurrent state has no sequence axis — it IS the decode cache
    and must pass through state_to_cache unchanged."""
    cfg = ARCHS["mamba2-130m"].reduced()
    state = {"ssm": object()}          # opaque: must come back identical
    cache, P = state_to_cache(cfg, None, state, 16, 1)
    assert cache is state and P == 0


def test_ring_cache_matches_full_cache():
    """Sliding-window ring cache (gemma2-style local/global) produces the
    same decode logits as the full-size cache at half the local-cache bytes."""
    cfg = tiny_dense(num_layers=4, sliding_window=12,
                     local_global_alternate=True, attn_softcap=50.0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    B, G = 2, 40                       # decode well past the window
    full = decode.init_decode_cache(cfg, B, G + 1)
    ring = decode.init_decode_cache(cfg, B, G + 1, ring_local=True)
    assert ring["k_local"].shape[2] == cfg.sliding_window
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, l: decode.decode_step(cfg, p, c, t, l))
    for pos in range(G):
        lf, full = step(params, full, tok, pos)
        lr, ring = step(params, ring, tok, pos)
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lf),
                                   rtol=3e-4, atol=3e-4)
        tok = jnp.argmax(lf[:, -1:], axis=-1).astype(jnp.int32)
