"""Numerical equivalence of the shard_map pipeline executor (4 fake devices).

Runs in a subprocess because XLA_FLAGS must be set before jax initializes
(and the rest of the suite must keep seeing 1 device).
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import chunking, chunked_step
from repro.models import api
from repro.distributed import pipeline

cfg = ModelConfig(name="tiny", family="dense", num_layers=4, d_model=32,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=61, dtype="float32", rope_theta=10_000.0)
S, C = 4, 16
mesh = jax.make_mesh((S,), ("pipe",))
params = api.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.RandomState(0)

# stream: one dependent group of 3 chunks + 2 standalone packed chunks
long_seq = rng.randint(1, cfg.vocab_size, size=3 * C).astype(np.int32)
lengths = {0: 3 * C, 1: 9, 2: 5, 3: 12, 4: 7}
seqs = {0: long_seq}
for i in (1, 2, 3, 4):
    seqs[i] = rng.randint(1, cfg.vocab_size, size=lengths[i]).astype(np.int32)
chunks = chunking.construct_chunks(lengths, C)
groups, standalone = chunking.group_chunks(chunks)
ordered = groups[0] + standalone
mats = [chunking.materialize_chunk(c, seqs) for c in ordered]
dep_flags = np.array([1 if c.dependent else 0 for c in ordered], np.int32)

batch = {k: jnp.asarray(np.concatenate([m[k] for m in mats], axis=0))
         for k in mats[0]}
batch = {k: v[:, None] if v.ndim == 1 else v[:, None, :] for k, v in batch.items()}
# shapes (M, B=1, T)
total = float(sum(m["loss_mask"].sum() for m in mats))
batch["dep_flags"] = jnp.asarray(dep_flags)
batch["loss_scale"] = jnp.float32(1.0 / total)

step = pipeline.make_pipeline_step(cfg, mesh, S, C)
loss, grads = step(params, batch)

# ---- reference: ChunkFlow single-device scheduler over the same chunks ----
gb = [[{k: jnp.asarray(v) for k, v in chunking.materialize_chunk(c, seqs).items()}
       for c in groups[0]]]
sb = [{k: jnp.asarray(v) for k, v in chunking.materialize_chunk(c, seqs).items()}
      for c in standalone]
ref_loss, ref_grads, _ = chunked_step.run_batch(cfg, params, gb, sb, k=1)

np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
jax.tree.map(
    lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                            rtol=2e-4, atol=3e-5),
    grads, ref_grads)
print("PIPELINE-EQUIVALENCE-OK")
"""


def test_pipeline_executor_matches_chunkflow_reference():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "PIPELINE-EQUIVALENCE-OK" in r.stdout, r.stdout + "\n" + r.stderr
