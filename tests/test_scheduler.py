"""Algorithm 2 schedule-generator tests (order, recompute count, memory bound)."""
import pytest

from repro.core.chunked_step import alg2_schedule


@pytest.mark.parametrize("n,k", [(1, 1), (2, 1), (4, 1), (4, 2), (4, 4),
                                 (7, 3), (16, 1), (16, 16), (5, 8)])
def test_schedule_invariants(n, k):
    ev = alg2_schedule(n, k)
    fwd = [e[1] for e in ev if e[0] == "F"]
    bwd = [e[1] for e in ev if e[0] == "B"]
    re = [e[1] for e in ev if e[0] == "F2"]
    assert fwd == list(range(n))                 # forwards ascending (§4.2)
    assert bwd == list(range(n))[::-1]           # backwards descending (§4.2)
    # the first N-K chunks are forwarded twice (§4.2 prose)
    assert re == list(range(max(n - k, 0)))[::-1]
    # every chunk backwarded exactly once
    assert sorted(bwd) == list(range(n))


@pytest.mark.parametrize("n,k", [(4, 1), (4, 2), (8, 3), (8, 8), (3, 5)])
def test_schedule_peak_residuals(n, k):
    """At most K chunks' activations (vjp residuals) are ever live."""
    live, peak = set(), 0
    for e in alg2_schedule(n, k):
        if e[0] == "F" and e[2]:
            live.add(e[1])
        elif e[0] == "F2":
            live.add(e[1])
        elif e[0] == "B":
            live.discard(e[1])
        peak = max(peak, len(live))
    assert peak <= max(k, 1)
    assert peak == min(max(k, 1), n)


def test_schedule_backward_dependency_order():
    """KV-grad dependency: chunk i's backward needs all j>i backwards done."""
    for n, k in [(4, 1), (6, 2), (5, 5)]:
        done = set()
        for e in alg2_schedule(n, k):
            if e[0] == "B":
                i = e[1]
                assert all(j in done for j in range(i + 1, n))
                done.add(i)
