"""chunklint (repro.analysis) test suite.

Three layers, mirroring the ISSUE acceptance criteria:

* fixture corpus: every check family detects its seeded violations
  (``*_bad.py``) and stays silent on the near-miss-but-valid siblings
  (``*_clean.py``);
* self-cleanliness: ``src/`` has zero unsuppressed findings under the
  committed baseline (and no stale suppressions);
* baseline round-trip: ``--update`` adopts current findings, a suppressed
  finding stops failing, and fixing the code prunes the stale entry.
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys

import pytest

from repro.analysis import ALL_CHECK_IDS, Baseline, run_analysis
from repro.analysis.core import load_axis_registry

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(TESTS_DIR, "fixtures", "analysis")
REPO = os.path.dirname(TESTS_DIR)
SRC = os.path.join(REPO, "src")
BASELINE = os.path.join(SRC, "repro", "analysis", "baseline.json")

# family -> exactly the check IDs its bad fixture must trigger
FAMILIES = {
    "mesh_axes": {"CF-AX01"},
    "ppermute": {"CF-RING01", "CF-RING02"},
    "custom_vjp": {"CF-VJP01", "CF-VJP02", "CF-VJP03", "CF-VJP05"},
    "pallas": {"CF-PL01", "CF-PL02", "CF-PL03"},
    "tracer": {"CF-TR01", "CF-TR02"},
    "donation": {"CF-DN01"},
}


def analyze_fixture(name: str):
    return run_analysis(
        [os.path.join(FIXTURES, name), os.path.join(FIXTURES, "launch")],
        repo_root=REPO)


# ------------------------------------------------------------ fixture corpus -
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_bad_fixture_detected(family):
    findings = analyze_fixture(f"{family}_bad.py")
    ids = {f.check_id for f in findings}
    assert ids == FAMILIES[family], [f.render() for f in findings]
    # every finding lands in the bad fixture itself, with a line and a hint
    for f in findings:
        assert f.path.endswith(f"{family}_bad.py")
        assert f.line > 0 and f.message
        assert f.hint, f"finding without a fix hint: {f.render()}"


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_clean_fixture_clean(family):
    findings = analyze_fixture(f"{family}_clean.py")
    assert findings == [], [f.render() for f in findings]


def test_every_check_id_has_fixture_coverage():
    covered = set().union(*FAMILIES.values())
    # CF-VJP04 (fwd arity) is exercised by the injection test below; CF-AX02
    # is the registry-missing meta-finding, exercised separately.
    assert covered == set(ALL_CHECK_IDS) - {"CF-VJP04", "CF-AX02"}


def test_fwd_arity_and_missing_registry(tmp_path):
    (tmp_path / "mod.py").write_text(
        "import jax\n"
        "from jax.sharding import PartitionSpec as P\n\n\n"
        "@jax.custom_vjp\n"
        "def f(x, y):\n"
        "    return x * y\n\n\n"
        "def f_fwd(x):\n"
        "    return x, (x,)\n\n\n"
        "def f_bwd(res, do):\n"
        "    (x,) = res\n"
        "    return do, do\n\n\n"
        "f.defvjp(f_fwd, f_bwd)\n"
        "SPEC = P('data')\n")
    ids = {f.check_id for f in run_analysis([str(tmp_path)])}
    # no mesh.py with MESH_AXES under the root -> CF-AX02, and the fwd
    # signature skew -> CF-VJP04
    assert ids == {"CF-VJP04", "CF-AX02"}


def test_finding_keys_are_line_stable():
    findings = analyze_fixture("mesh_axes_bad.py")
    for f in findings:
        assert str(f.line) not in f.key.split("::")[-1]
        assert f.key.startswith(f"{f.check_id}::")


# ---------------------------------------------------------- self-cleanliness -
def test_src_self_clean():
    findings = run_analysis([SRC], repo_root=REPO)
    unsup, _, stale = Baseline(BASELINE).split(findings)
    assert unsup == [], "\n".join(f.render() for f in unsup)
    assert stale == [], f"stale baseline entries: {stale}"


def test_axis_registry_matches_runtime():
    from repro.launch.mesh import MESH_AXES
    assert load_axis_registry([SRC]) == frozenset(MESH_AXES)


def test_injected_axis_typo_is_caught(tmp_path):
    """The acceptance-criterion scratch test: copy a real executor source,
    typo one axis string, and the analyzer must fail on the copy."""
    work = tmp_path / "tree"
    (work / "launch").mkdir(parents=True)
    shutil.copy(os.path.join(SRC, "repro", "launch", "mesh.py"),
                work / "launch" / "mesh.py")
    with open(os.path.join(
            SRC, "repro", "distributed", "context_parallel.py")) as fh:
        real = fh.read()
    assert 'P("data", AXIS)' in real
    (work / "executor.py").write_text(
        real.replace('P("data", AXIS)', 'P("dtaa", AXIS)', 1))
    findings = run_analysis([str(work)])
    assert any(f.check_id == "CF-AX01" and '"dtaa"' in f.message
               for f in findings), [f.render() for f in findings]
    # and the pristine copy stays clean
    (work / "executor.py").write_text(real)
    assert run_analysis([str(work)]) == []


# ------------------------------------------------------- baseline round-trip -
def test_baseline_roundtrip(tmp_path):
    work = tmp_path / "proj"
    shutil.copytree(os.path.join(FIXTURES, "launch"), work / "launch")
    shutil.copy(os.path.join(FIXTURES, "ppermute_bad.py"), work / "mod.py")
    bpath = str(tmp_path / "baseline.json")

    findings = run_analysis([str(work)])
    assert findings
    keys = {f.key for f in findings}   # baseline dedups by line-stable key

    # --update adopts every current finding
    bl = Baseline(bpath)
    added, pruned = bl.update(findings)
    assert set(added) == keys and not pruned

    # reloaded baseline suppresses everything, nothing stale
    unsup, sup, stale = Baseline(bpath).split(run_analysis([str(work)]))
    assert unsup == [] and len(sup) == len(findings) and stale == []

    # hand-edited reasons survive a no-op --update
    bl2 = Baseline(bpath)
    k0 = sorted(bl2.suppressions)[0]
    bl2.suppressions[k0] = "documented false positive"
    bl2.update(run_analysis([str(work)]))
    assert Baseline(bpath).suppressions[k0] == "documented false positive"

    # fixing the code makes the entries stale; --update prunes them
    shutil.copy(os.path.join(FIXTURES, "ppermute_clean.py"), work / "mod.py")
    clean = run_analysis([str(work)])
    unsup, sup, stale = Baseline(bpath).split(clean)
    assert unsup == [] and sup == [] and set(stale) == keys
    added, pruned = Baseline(bpath).update(clean)
    assert not added and set(pruned) == keys
    assert Baseline(bpath).suppressions == {}


# ----------------------------------------------------------------------- CLI -
def _cli(*args, cwd=REPO):
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_src_and_exit_codes(tmp_path):
    r = _cli("src", "--baseline", BASELINE)
    assert r.returncode == 0, r.stdout + r.stderr

    report = str(tmp_path / "report.json")
    bad = os.path.join(FIXTURES, "mesh_axes_bad.py")
    r = _cli(bad, os.path.join(FIXTURES, "launch"),
             "--no-baseline", "--json", report)
    assert r.returncode == 1
    with open(report) as fh:
        payload = json.load(fh)
    assert payload["unsuppressed"] and payload["stale_baseline_keys"] == []
    assert {f["check_id"] for f in payload["unsuppressed"]} == {"CF-AX01"}


def test_cli_stale_baseline_fails(tmp_path):
    """A suppression whose finding no longer fires must fail the run (the
    orphan-gate idiom): stale entries are blanket permission for future
    bugs at the same site."""
    bpath = tmp_path / "baseline.json"
    bpath.write_text(json.dumps(
        {"suppressions": {"CF-AX01::gone.py::PartitionSpec:xyz": "stale"}}))
    clean = os.path.join(FIXTURES, "mesh_axes_clean.py")
    r = _cli(clean, os.path.join(FIXTURES, "launch"),
             "--baseline", str(bpath))
    assert r.returncode == 1
    assert "stale" in r.stdout


def test_cli_list_checks():
    r = _cli("--list-checks")
    assert r.returncode == 0
    for cid in ALL_CHECK_IDS:
        assert cid in r.stdout
