"""Distribution-layer tests: sharding rules are valid for every arch, and a
reduced-config train/decode step lowers + compiles on a small SPMD mesh."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHS
from repro.launch import specs as specs_lib


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_cover_every_leaf(arch):
    """Every param leaf gets a PartitionSpec whose sharded dims divide."""
    from repro.distributed import sharding
    cfg = ARCHS[arch]
    pshape = specs_lib.params_shape(cfg, max_seq=4096)
    mesh_sizes = {"data": 16, "model": 16}

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), object)

    pspecs = sharding.param_specs(cfg, pshape, FakeMesh())
    flat_s, _ = jax.tree_util.tree_flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree_util.tree_leaves(pshape)
    assert len(flat_s) == len(flat_p)
    n_sharded = 0
    for spec, leaf in zip(flat_s, flat_p):
        assert isinstance(spec, P)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([mesh_sizes[a] for a in axes]))
            assert leaf.shape[dim] % total == 0, (spec, leaf.shape)
            n_sharded += 1
    assert n_sharded > 0  # something actually shards


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import InputShape
from repro.configs.registry import ARCHS
from repro.launch import specs as specs_lib
from repro.distributed import sharding

mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = InputShape("mini_train", "train", 128, 8)
dshape = InputShape("mini_decode", "decode", 256, 8)

for arch in ("granite-3-8b", "granite-moe-1b-a400m", "mamba2-130m",
             "gemma2-2b"):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), name=ARCHS[arch].name)
    args, shardings, step = specs_lib.input_specs(cfg, shape, mesh)
    with mesh:
        c = jax.jit(step, in_shardings=shardings).lower(*args).compile()
    assert c.memory_analysis() is not None
    args, shardings, step = specs_lib.input_specs(cfg, dshape, mesh)
    with mesh:
        jax.jit(step, in_shardings=shardings).lower(*args).compile()
    print(f"{arch} OK")
print("MINI-DRYRUN-OK")
"""


def test_mini_dryrun_on_8_devices():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=540)
    assert "MINI-DRYRUN-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
