"""Compile-count regression: the static-shape StateStore must make the
jitted chunk fn compile O(#capacity buckets) times for a mixed batch of
group sizes — NOT once per chunk index (the grow-by-C prefix pathology).

We count *Python retraces* of the chunk fn (chunked_step.TRACE_EVENTS logs
one entry per trace, which is 1:1 with fresh XLA compiles for a jitted fn).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chunked_step, chunking
from repro.core.dp_balance import prefix_capacity
from repro.models import api
from test_chunked_equivalence import tiny

C = 16


def _batchify(cfg, rng, lengths):
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in lengths.items()}
    chunks = chunking.construct_chunks(lengths, C)
    groups, standalone = chunking.group_chunks(chunks)
    gb = [[{k: jnp.asarray(v) for k, v in
            chunking.materialize_chunk(c, seqs).items()} for c in g]
          for g in groups.values()]
    sb = [{k: jnp.asarray(v) for k, v in
           chunking.materialize_chunk(c, seqs).items()} for c in standalone]
    return gb, sb


def test_prefix_capacity_buckets():
    assert prefix_capacity(1, C) == 0
    assert prefix_capacity(2, C) == C
    assert prefix_capacity(3, C) == 2 * C
    assert prefix_capacity(4, C) == 4 * C
    assert prefix_capacity(5, C) == 4 * C       # shares the n=4 bucket
    assert prefix_capacity(8, C) == 8 * C
    assert prefix_capacity(9, C) == 8 * C


def test_chunk_fn_compiles_per_bucket_not_per_chunk():
    cfg = tiny("dense", name="compile-count")   # fresh lru_cache key
    rng = np.random.RandomState(0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    chunked_step.reset_trace_log()

    # group sizes {1, 2, 4}: capacity buckets {0, C, 4C}
    gb, sb = _batchify(cfg, rng, {0: C, 1: 2 * C, 2: 4 * C})
    loss, grads, _ = chunked_step.run_batch(cfg, params, gb, sb, k=1)
    assert np.isfinite(float(loss))
    n_first = len(chunked_step.TRACE_EVENTS)
    shapes = {(p, c) for _, p, c in chunked_step.TRACE_EVENTS}
    assert shapes == {(0, C), (C, C), (4 * C, C)}, shapes
    # one trace per bucket — with grow-by-C prefixes this would be 4 distinct
    # prefix lengths {0, C, 2C, 3C} and grow with the longest group.
    assert n_first == len(shapes), chunked_step.TRACE_EVENTS

    # same batch again: fully cached, zero new traces
    chunked_step.run_batch(cfg, params, gb, sb, k=1)
    assert len(chunked_step.TRACE_EVENTS) == n_first

    # a *5*-chunk group shares the n=4 bucket (cap 4C): zero new compiles,
    # even though chunk indices 0..4 were never run at these prefix lengths.
    gb5, sb5 = _batchify(cfg, rng, {0: 5 * C})
    assert len(gb5[0]) == 5 and not sb5
    chunked_step.run_batch(cfg, params, gb5, sb5, k=1)
    assert len(chunked_step.TRACE_EVENTS) == n_first, \
        chunked_step.TRACE_EVENTS

    # an 8-chunk group opens exactly one new bucket (8C)
    gb8, sb8 = _batchify(cfg, rng, {0: 8 * C})
    chunked_step.run_batch(cfg, params, gb8, sb8, k=2)
    assert len(chunked_step.TRACE_EVENTS) == n_first + 1
    chunked_step.reset_trace_log()


def test_loss_matches_across_bucket_sharing():
    """Sanity: a 5-chunk group (running in the padded n=4 bucket) still
    produces the exact full-sequence loss."""
    from test_chunked_equivalence import chunked_run, full_reference
    cfg = tiny("dense", name="compile-count-loss")
    rng = np.random.RandomState(1)
    seq = rng.randint(1, cfg.vocab_size, size=5 * C).astype(np.int32)
    params = api.init_params(cfg, jax.random.PRNGKey(2))
    ref_loss, _ = full_reference(cfg, params, seq)
    loss, _, _ = chunked_run(cfg, params, seq, C, 1)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
