"""Pipeline-schedule simulator vs the paper's OWN numbers (Figs. 2, 6, 7).

Reproduced exactly:
  * equal-length 4-microbatch 1F1B, P=4      -> 42.86%  (paper: "42.8%")
  * Fig. 2  variable [4,2,1,1]               -> 57.14%  (paper: 57.14%)
  * Fig. 7  ChunkSize=4*Unit, 2 chunks       -> 60.00%  (paper: 60%)
  * Fig. 6  state-aware, paper-K=2           -> 47.83%  (paper: 47.8%)
  * improvements: paper-K=1 -> 7.7% ("approximately 8%"), K=1->K=2 -> 11.5%
    ("12%")

K-convention note (EXPERIMENTS.md §Dry-run): the paper's pipeline figures use
K counting the *in-flight* chunk's activation slot, so paper-K corresponds to
sim-k = paper-K - 1 in `chunks_to_microbatches`. Fig. 6(a) (paper-K=1,
recompute everything) lands at 53.85% vs the paper's 54.1% — the 0.25pp gap
is the hand-drawn figure's schedule; the derived improvement (7.7%~"8%")
matches.
"""
import numpy as np
import pytest

from repro.core.chunking import construct_chunks
from repro.core.schedule_sim import (Microbatch, chunks_to_microbatches,
                                     rotation_windows,
                                     sequences_to_microbatches,
                                     simulate_1f1b, simulate_rotation)

LENGTHS = {0: 4, 1: 2, 2: 1, 3: 1}     # Fig. 2(a), longest-first order


def test_equal_length_baseline():
    r = simulate_1f1b(sequences_to_microbatches([1, 1, 1, 1]), 4)
    assert abs(r.bubble_ratio - 3 / 7) < 1e-9          # 42.857%


def test_fig2_variable_length_1f1b():
    r = simulate_1f1b(sequences_to_microbatches([4, 2, 1, 1]), 4)
    assert abs(r.bubble_ratio - 0.5714) < 2e-4          # 57.14%
    # variable lengths strictly worse than the equal-length bound
    assert r.bubble_ratio > 3 / 7


def test_fig7_chunksize_too_large():
    chunks = construct_chunks(LENGTHS, 4)               # -> only 2 chunks
    assert len(chunks) == 2
    r = simulate_1f1b(chunks_to_microbatches(chunks, k=1), 4, state_aware=True)
    assert abs(r.bubble_ratio - 0.60) < 1e-9            # 60%
    base = simulate_1f1b(sequences_to_microbatches([4, 2, 1, 1]), 4)
    assert r.makespan > base.makespan                   # the degradation


def _fig6_chunks():
    chunks = construct_chunks(LENGTHS, 2)
    assert len(chunks) == 4
    assert all(c.tokens_used == 2 for c in chunks)
    return chunks


def test_fig6_paper_k2():
    mbs = chunks_to_microbatches(_fig6_chunks(), k=1)   # paper-K=2
    r = simulate_1f1b(mbs, 4, state_aware=True)
    assert abs(r.bubble_ratio - 0.4783) < 1e-3          # paper: 47.8%


def test_fig6_paper_k1_and_improvements():
    base = simulate_1f1b(sequences_to_microbatches([4, 2, 1, 1]), 4)
    chunks = _fig6_chunks()
    # paper-K=1: recompute every dependent chunk (sim-k=0), standalone first
    std = [c for c in chunks if not c.dependent]
    dep = [c for c in chunks if c.dependent]
    mbs1 = chunks_to_microbatches(std + dep, k=0)
    r1 = simulate_1f1b(mbs1, 4, state_aware=True)
    assert 0.53 <= r1.bubble_ratio <= 0.545             # paper: 54.1%
    imp1 = (base.makespan - r1.makespan) / base.makespan
    assert 0.06 <= imp1 <= 0.09                         # "approximately 8%"

    mbs2 = chunks_to_microbatches(chunks, k=1)          # paper-K=2
    r2 = simulate_1f1b(mbs2, 4, state_aware=True)
    imp2 = (r1.makespan - r2.makespan) / r1.makespan
    assert 0.10 <= imp2 <= 0.13                         # "12% enhancement"


def test_state_aware_beats_baseline_on_longtail_batches():
    """Property: over random long-tail batches, chunked state-aware 1F1B never
    increases makespan vs raw variable-length 1F1B (with a tuned ChunkSize)."""
    rng = np.random.RandomState(0)
    for _ in range(20):
        n = rng.randint(4, 12)
        lens = [int(l) for l in np.ceil(rng.pareto(1.2, size=n) + 1)]
        lens = [min(l, 64) for l in lens]
        base = simulate_1f1b(
            sequences_to_microbatches(sorted(lens, reverse=True)), 4)
        best = None
        for C in (2, 4, 8, 16):
            chunks = construct_chunks(dict(enumerate(lens)), C)
            for k in (0, 1, 2):
                r = simulate_1f1b(chunks_to_microbatches(chunks, k=k), 4,
                                  state_aware=True)
                best = min(best, r.makespan) if best else r.makespan
        assert best <= base.makespan * 1.0 + 1e-9


def test_recompute_accounting():
    mbs = [Microbatch(2.0, group=0, index_in_group=0, group_size=2,
                      recompute=True),
           Microbatch(2.0, group=0, index_in_group=1, group_size=2)]
    r = simulate_1f1b(mbs, 2, state_aware=True)
    assert r.recompute_time == 2.0 * 2                  # once per stage


# ------------------------------------------------- SPMD rotation schedule ---
def test_rotation_windows_partition():
    for n in range(1, 12):
        for k in range(1, 12):
            wins = rotation_windows(n, k)
            assert sum(wins) == n
            assert all(w >= 1 for w in wins)
            assert max(wins) <= max(1, k)
            # recompute count matches alg2_schedule's keep_from = N - K
            assert n - wins[-1] == max(n - max(1, k), 0)
    assert rotation_windows(5, 2) == [1, 2, 2]
    assert rotation_windows(4, 2) == [2, 2]
    assert rotation_windows(3, 5) == [3]
    assert rotation_windows(0, 2) == []


def test_rotation_closed_form_single_wave():
    # one wave of 4 chunks, 2 stages, K=2: windows [2, 2]
    r = simulate_rotation([4], 2, 2)
    # F(2)=3 + F2(2)=3 + F(2)=3 ticks, B scans 2*(3+3)
    assert r.makespan == 3 + 3 + 3 + 2 * (3 + 3)
    assert r.useful_time == 3 * 4 * 2
    assert r.recompute_time == 2 * 2                    # 2 chunks x 2 stages
    assert r.recompute_count == 2
    assert r.peak_resident_chunks == 2
    assert r.kv_capacity_slots == [4]                   # pow2(4-1) bucket
    assert abs(r.bubble_ratio
               - (2 * r.makespan - r.useful_time) / (2 * r.makespan)) < 1e-12


def test_rotation_k_tradeoff_monotone():
    """Larger K: fewer recomputes and fewer scan fills -> makespan and bubble
    never increase; resident chunk-states never decrease."""
    for S in (2, 4, 8):
        prev = None
        for k in (1, 2, 4, 8):
            r = simulate_rotation([8, 3, 1], S, k)
            assert r.recompute_count == max(8 - k, 0) + max(3 - k, 0)
            if prev is not None:
                assert r.makespan <= prev.makespan
                assert r.bubble_ratio <= prev.bubble_ratio + 1e-12
                assert r.peak_resident_chunks >= prev.peak_resident_chunks
            prev = r


def test_rotation_vs_1f1b_documented_delta():
    """The rotation pays lockstep fill/drain every window scan, so at K=N it
    degenerates to one F scan + one B scan: bubble = exactly the classic
    (S-1)-per-scan fill cost. The 1F1B sim of the same uniform stream is the
    asynchronous lower bound and must never be worse."""
    S, n = 4, 8
    rot = simulate_rotation([n], S, n)
    total = S * rot.makespan
    fill = 3 * S * (S - 1)          # F scan fill (1x) + B scan fill (2x)
    assert total - rot.useful_time == fill
    f1b = simulate_1f1b(sequences_to_microbatches([1.0] * n), S)
    assert f1b.bubble_ratio <= rot.bubble_ratio + 1e-12
    # at K=N on uniform chunks the two schedules coincide exactly
    assert abs(f1b.bubble_ratio - rot.bubble_ratio) < 1e-12
