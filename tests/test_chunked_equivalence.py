"""THE core correctness claim (paper §4, Fig. 3 caption): ChunkFlow's chunked
execution with state-aware scheduling + gradient accumulation is
mathematically equivalent to full-sequence training.

We compare loss AND full parameter gradients between (a) one full-sequence
step and (b) Algorithm 2 over the constructed chunks, for every family that
carries state (attention KV, SSD state, hybrid both, whisper enc+KV), across
K values straddling N.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs.base import ModelConfig
from repro.core import chunking, chunked_step
from repro.models import api

jax.config.update("jax_enable_x64", False)


def tiny(family, **kw):
    base = dict(
        name=f"tiny-{family}", family=family, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97,
        dtype="float32", rope_theta=10_000.0)
    if family == "moe":
        base.update(num_experts=4, experts_per_token=2, router_aux_coef=0.0,
                    capacity_factor=8.0)   # generous: no token drops
    if family == "ssm":
        base.update(num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16,
                    ssm_head_dim=32, ssm_chunk=16)
    if family == "hybrid":
        base.update(num_experts=4, experts_per_token=2, router_aux_coef=0.0,
                    capacity_factor=8.0, attn_every=2, ssm_state=16,
                    ssm_head_dim=32, ssm_chunk=16)
    if family == "audio":
        base.update(is_encoder_decoder=True, encoder_layers=2, encoder_seq=16,
                    rope_theta=0.0)
    base.update(kw)
    return ModelConfig(**base)


def full_reference(cfg, params, seq, extra=None):
    """Single full-sequence step: loss (token-mean) + grads."""
    T = len(seq)
    batch = {
        "tokens": jnp.asarray(seq[None]),
        "labels": jnp.asarray(np.concatenate([seq[1:], [0]])[None]),
        "segment_ids": jnp.ones((1, T), jnp.int32),
        "positions": jnp.arange(T, dtype=jnp.int32)[None],
        "loss_mask": jnp.asarray(
            np.concatenate([np.ones(T - 1), [0.0]])[None], jnp.float32),
    }
    if extra:
        batch.update(extra)
    scale = 1.0 / (T - 1)

    def loss_fn(p):
        logits, _, aux = api.forward(cfg, p, batch)
        return (chunked_step.token_nll_sum(
            logits, batch["labels"], batch["loss_mask"]) + aux["moe_aux"]) * scale

    return jax.value_and_grad(loss_fn)(params)


def chunked_run(cfg, params, seq, chunk_size, k, extra_first=None):
    chunks = chunking.construct_chunks({0: len(seq)}, chunk_size)
    groups, standalone = chunking.group_chunks(chunks)
    assert not standalone
    mats = [chunking.materialize_chunk(c, {0: np.asarray(seq)})
            for c in groups[0]]
    batches = []
    for i, m in enumerate(mats):
        b = {kk: jnp.asarray(v) for kk, v in m.items()}
        if i == 0 and extra_first:
            b.update(extra_first)
        batches.append(b)
    scale = 1.0 / (len(seq) - 1)
    loss, grads, stats = chunked_step.run_group(
        cfg, params, batches, k=k, loss_scale=scale)
    return loss, grads, stats


def assert_trees_close(a, b, rtol=2e-4, atol=2e-5):
    fa, ta = jax.tree.flatten(a)
    fb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid", "audio"])
@pytest.mark.parametrize("k", [1, 2, 8])
def test_chunked_equals_full(family, k):
    cfg = tiny(family)
    rng = np.random.RandomState(0)
    T, C = 96, 32            # 3 dependent chunks
    seq = rng.randint(1, cfg.vocab_size, size=T).astype(np.int32)
    params = api.init_params(cfg, jax.random.PRNGKey(1), max_seq=T + 8)

    extra = None
    if family == "audio":
        enc = jnp.asarray(rng.randn(1, cfg.encoder_seq, cfg.d_model),
                          jnp.float32)
        extra = {"encoder_embeds": enc}

    ref_loss, ref_grads = full_reference(cfg, params, seq, extra)
    loss, grads, stats = chunked_run(cfg, params, seq, C, k, extra)

    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    assert_trees_close(grads, ref_grads)
    # scheduler memory bound held
    assert stats.max_live_residuals <= max(k, 1)
    n = T // C
    assert stats.recompute_calls == max(n - k, 0)


def test_gemma2_variant_chunked():
    """Sliding-window + softcap variant also survives chunking."""
    cfg = tiny("dense", sliding_window=40, local_global_alternate=True,
               attn_softcap=50.0, logit_softcap=30.0, tie_embeddings=True)
    rng = np.random.RandomState(1)
    seq = rng.randint(1, cfg.vocab_size, size=96).astype(np.int32)
    params = api.init_params(cfg, jax.random.PRNGKey(2))
    ref_loss, ref_grads = full_reference(cfg, params, seq)
    loss, grads, _ = chunked_run(cfg, params, seq, 32, 1)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    assert_trees_close(grads, ref_grads)


def test_packed_standalone_equals_separate():
    """Packing short sequences into one chunk == processing them separately
    (attention families are exactly segment-isolated)."""
    cfg = tiny("dense")
    rng = np.random.RandomState(2)
    lens = [10, 7, 13]
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in enumerate(lens)}
    params = api.init_params(cfg, jax.random.PRNGKey(3))
    chunks = chunking.construct_chunks({i: l for i, l in enumerate(lens)}, 32)
    assert len(chunks) == 1
    m = {k: jnp.asarray(v) for k, v in
         chunking.materialize_chunk(chunks[0], seqs).items()}
    total = sum(l - 1 for l in lens)
    loss_packed, grads_packed, _ = chunked_step.run_group(
        cfg, params, [m], k=1, loss_scale=1.0 / total)

    ref_loss, ref_grads, acc = 0.0, None, None
    for _i, s in seqs.items():
        l, g = full_reference(cfg, params, s)
        w = (len(s) - 1) / total
        ref_loss += float(l) * w
        acc = jax.tree.map(lambda a, b: a + b * w, acc, g) if acc else \
            jax.tree.map(lambda b: b * w, g)
    np.testing.assert_allclose(float(loss_packed), ref_loss, rtol=1e-5)
    assert_trees_close(grads_packed, acc, rtol=5e-4, atol=5e-5)


def test_mixed_batch_run():
    """run_batch over a realistic long-tail mini-batch: 1 long + shorts."""
    cfg = tiny("dense")
    rng = np.random.RandomState(3)
    lengths = {0: 80, 1: 9, 2: 14, 3: 5, 4: 30}
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in lengths.items()}
    params = api.init_params(cfg, jax.random.PRNGKey(4))
    chunks = chunking.construct_chunks(lengths, 32)
    groups, standalone = chunking.group_chunks(chunks)
    gb = [[{k: jnp.asarray(v) for k, v in
            chunking.materialize_chunk(c, seqs).items()} for c in g]
          for g in groups.values()]
    sb = [{k: jnp.asarray(v) for k, v in
           chunking.materialize_chunk(c, seqs).items()} for c in standalone]
    loss, grads, stats = chunked_step.run_batch(cfg, params, gb, sb, k=1)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))

    # reference: weighted sum over individual sequences
    total = sum(l - 1 for l in lengths.values())
    ref_loss, acc = 0.0, None
    for _i, s in seqs.items():
        l, g = full_reference(cfg, params, s)
        w = (len(s) - 1) / total
        ref_loss += float(l) * w
        acc = jax.tree.map(lambda a, b: a + b * w, acc, g) if acc else \
            jax.tree.map(lambda b: b * w, g)
    np.testing.assert_allclose(float(loss), ref_loss, rtol=1e-5)
    assert_trees_close(grads, acc, rtol=5e-4, atol=5e-5)


from hypcompat import given, settings, st


@given(st.integers(40, 140), st.sampled_from([16, 32, 48]),
       st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_chunked_equivalence_property(T, C, k):
    """Hypothesis sweep: any (seq_len, ChunkSize, K) combination preserves
    loss + gradients vs the full-sequence step (dense family)."""
    cfg = tiny("dense")
    rng = np.random.RandomState(T * 1000 + C + k)
    seq = rng.randint(1, cfg.vocab_size, size=T).astype(np.int32)
    params = api.init_params(cfg, jax.random.PRNGKey(0), max_seq=T + 8)
    ref_loss, ref_grads = full_reference(cfg, params, seq)
    if T <= C:
        # single standalone chunk path
        chunks = chunking.construct_chunks({0: T}, C)
        m = {kk: jnp.asarray(v) for kk, v in
             chunking.materialize_chunk(chunks[0], {0: seq}).items()}
        loss, grads, _ = chunked_step.run_group(
            cfg, params, [m], k=k, loss_scale=1.0 / (T - 1))
    else:
        loss, grads, _ = chunked_run(cfg, params, seq, C, k)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=2e-5)
    assert_trees_close(grads, ref_grads, rtol=5e-4, atol=5e-5)
