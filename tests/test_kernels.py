"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.chunked_attention import chunked_prefix_attention
from repro.kernels.decode_attention import decode_attention


def rand_attn(key, B, T, P, Hq, Hkv, D, dtype, packed=False):
    ks = jax.random.split(key, 5)
    S = P + T
    q = jax.random.normal(ks[0], (B, Hq, T, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32).astype(dtype)
    if packed:
        # two segments per row splitting T at a random-ish point; no prefix
        assert P == 0
        split = T // 3
        q_seg = jnp.where(jnp.arange(T) < split, 1, 2)[None].repeat(B, 0)
        q_pos = jnp.where(jnp.arange(T) < split, jnp.arange(T),
                          jnp.arange(T) - split)[None].repeat(B, 0)
        k_seg, k_pos = q_seg, q_pos
    else:
        q_pos = (P + jnp.arange(T))[None].repeat(B, 0)
        q_seg = jnp.ones((B, T), jnp.int32)
        k_pos = jnp.arange(S)[None].repeat(B, 0)
        k_seg = jnp.ones((B, S), jnp.int32)
    return q, k, v, q_pos, k_pos, q_seg, k_seg


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,P,Hq,Hkv,D", [
    (1, 128, 0, 4, 2, 64),        # no prefix (standalone chunk)
    (2, 128, 128, 4, 4, 64),      # MHA with one-chunk prefix
    (1, 256, 128, 8, 2, 128),     # GQA, longer chunk
    (1, 128, 384, 4, 1, 128),     # deep prefix (chunk 4 of a long seq)
])
def test_chunked_prefix_attention_matches_ref(dtype, B, T, P, Hq, Hkv, D):
    args = rand_attn(jax.random.PRNGKey(0), B, T, P, Hq, Hkv, D, dtype)
    out = chunked_prefix_attention(*args, interpret=True)
    expect = ref.chunked_prefix_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


def test_chunked_attention_packed_segments():
    args = rand_attn(jax.random.PRNGKey(1), 2, 128, 0, 4, 2, 64,
                     jnp.float32, packed=True)
    out = chunked_prefix_attention(*args, interpret=True)
    expect = ref.chunked_prefix_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (96, 0.0), (0, 50.0),
                                            (64, 30.0)])
def test_chunked_attention_window_softcap(window, softcap):
    args = rand_attn(jax.random.PRNGKey(2), 1, 128, 128, 4, 2, 64, jnp.float32)
    out = chunked_prefix_attention(*args, window=window, softcap=softcap,
                                   interpret=True)
    expect = ref.chunked_prefix_attention_ref(*args, window=window,
                                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_ops_wrapper_pads_and_matches_layers_layout():
    """The (B,T,H,D) wrapper with non-block-aligned T/S."""
    B, T, P, Hq, Hkv, D = 2, 100, 60, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, P + T, Hkv, D))
    v = jax.random.normal(ks[2], (B, P + T, Hkv, D))
    q_pos = (P + jnp.arange(T))[None].repeat(B, 0)
    k_pos = jnp.arange(P + T)[None].repeat(B, 0)
    q_seg = jnp.ones((B, T), jnp.int32)
    k_seg = jnp.ones((B, P + T), jnp.int32)
    out = ops.chunk_attention(q, k, v, q_pos, k_pos, q_seg, k_seg,
                              block_q=64, block_k=64)
    expect = ref.chunked_prefix_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_pos, k_pos, q_seg, k_seg)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(expect), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,D,clen,window", [
    (1, 256, 4, 2, 64, 200, 0),
    (2, 512, 8, 8, 128, 17, 0),
    (1, 256, 4, 1, 128, 255, 0),
    (2, 256, 4, 2, 64, 250, 128),     # sliding window decode
])
def test_decode_attention_matches_ref(dtype, B, S, Hq, Hkv, D, clen, window):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32).astype(dtype)
    out = decode_attention(q, k, v, clen, window=window, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, clen, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOL[dtype])


def test_kernel_equals_model_sdpa_path():
    """Kernel output == the model's sdpa attention (same masking contract)."""
    from repro.models import layers as L
    B, T, P, Hq, Hkv, D = 1, 128, 128, 4, 2, 64
    args = rand_attn(jax.random.PRNGKey(5), B, T, P, Hq, Hkv, D, jnp.float32)
    q, k, v, q_pos, k_pos, q_seg, k_seg = args
    out = chunked_prefix_attention(*args, interpret=True)
    mask = L.make_attention_mask(q_pos, k_pos, q_seg, k_seg, causal=True)
    expect = L.sdpa(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(
        expect.transpose(0, 2, 1, 3)), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,nc,l,S,H,P", [
    (1, 2, 128, 32, 4, 64),
    (2, 1, 256, 64, 2, 32),
])
def test_ssd_intra_chunk_matches_ref(dtype, B, nc, l, S, H, P):
    from repro.kernels.ssd_scan import ssd_intra_chunk
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    Cc = jax.random.normal(ks[0], (B, nc, l, S), jnp.float32).astype(dtype)
    Bc = jax.random.normal(ks[1], (B, nc, l, S), jnp.float32).astype(dtype)
    # decays: negative cumulative sums (realistic SSD magnitudes)
    dA = -jnp.abs(jax.random.normal(ks[2], (B, nc, l, H))) * 0.05
    dA_cum = jnp.cumsum(dA, axis=2).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, nc, l, H))).astype(dtype)
    xc = jax.random.normal(ks[4], (B, nc, l, H, P), jnp.float32).astype(dtype)
    out = ssd_intra_chunk(Cc, Bc, dA_cum, dt, xc, interpret=True)
    expect = ref.ssd_intra_chunk_ref(Cc, Bc, dA_cum, dt, xc)
    # SSD outputs are O(sqrt(l)*S)-scale sums (not convex combinations like
    # attention), so bf16 needs a scale-relative tolerance
    scale = float(np.abs(np.asarray(expect, np.float32)).max())
    tol = (dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32
           else dict(rtol=5e-2, atol=5e-2 * scale))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol)


def test_ssd_kernel_matches_model_scan():
    """Kernel y_intra == the model's _ssd_chunk_scan y_intra path (zero
    initial state, single segment -> y == y_intra for the first chunk)."""
    from repro.kernels.ssd_scan import ssd_intra_chunk
    from repro.models.mamba2 import _ssd_chunk_scan
    B, T, H, P, S, l = 1, 128, 2, 32, 16, 128
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    xh = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.abs(jax.random.normal(ks[2], (H,))) * 0.1
    Bm = jax.random.normal(ks[3], (B, T, S))
    Cm = jax.random.normal(jax.random.PRNGKey(9), (B, T, S))
    y_model, _ = _ssd_chunk_scan(xh, dt, A, Bm, Cm, chunk=l)
    dA_cum = jnp.cumsum(dt * A, axis=1).reshape(B, 1, l, H)
    y_kernel = ssd_intra_chunk(Cm.reshape(B, 1, l, S), Bm.reshape(B, 1, l, S),
                               dA_cum, dt.reshape(B, 1, l, H),
                               xh.reshape(B, 1, l, H, P), interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel[:, 0]),
                               np.asarray(y_model), rtol=2e-4, atol=2e-4)


def test_pallas_backend_matches_xla_in_model():
    """cfg.attn_backend='pallas_interpret' plugs the kernel into the full
    model forward; logits must match the XLA path."""
    import dataclasses
    from repro.configs.base import ModelConfig
    from repro.models import api
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=97, dtype="float32", rope_theta=10_000.0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 48), 1,
                                          cfg.vocab_size)}
    ref_logits, ref_state, _ = api.forward(cfg, params, batch)
    cfgp = dataclasses.replace(cfg, attn_backend="pallas_interpret")
    out_logits, out_state, _ = api.forward(cfgp, params, batch)
    np.testing.assert_allclose(np.asarray(out_logits),
                               np.asarray(ref_logits), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_state["k"]),
                               np.asarray(ref_state["k"]), rtol=2e-5,
                               atol=2e-5)
