"""MoE layer: scatter-dispatch vs dense per-token reference + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib


def mk_cfg(E=4, K=2, D=32, F=64, cf=8.0):
    return ModelConfig(name="m", family="moe", num_layers=1, d_model=D,
                       num_heads=2, num_kv_heads=1, d_ff=F, vocab_size=64,
                       num_experts=E, experts_per_token=K,
                       capacity_factor=cf, dtype="float32")


def dense_reference(p, x, cfg):
    """Compute every expert on every token, combine with top-k gates."""
    B, T, D = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / gates.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("btd,edf->betf", x, p["w_gate"]))
    h = h * jnp.einsum("btd,edf->betf", x, p["w_up"])
    y_all = jnp.einsum("betf,efd->betd", h, p["w_down"])     # (B,E,T,D)
    out = jnp.zeros_like(x)
    for k in range(cfg.experts_per_token):
        sel = jnp.take_along_axis(
            y_all, idx[..., k][:, None, :, None], axis=1)[:, 0]
        out = out + sel * gates[..., k][..., None]
    return out


@pytest.mark.parametrize("E,K", [(4, 1), (4, 2), (8, 3)])
def test_moe_matches_dense_reference(E, K):
    cfg = mk_cfg(E=E, K=K)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_lib.moe_layer(p, x, cfg)
    ref = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most tokens are dropped, none corrupted."""
    cfg = mk_cfg(E=4, K=2, cf=0.2)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    out, _ = moe_lib.moe_layer(p, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    ref = dense_reference(p, x, cfg)
    # dropped tokens output a smaller-norm combination than the full ref
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(ref)) + 1e-3


def test_moe_grads_flow_to_all_param_groups():
    cfg = mk_cfg()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))

    def loss(p):
        out, aux = moe_lib.moe_layer(p, x, cfg)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert float(jnp.abs(v).sum()) > 0, f"no grad for {k}"


@given(st.integers(2, 6), st.integers(1, 3), st.integers(4, 32))
@settings(max_examples=10, deadline=None)
def test_moe_property_finite_and_shaped(E, K, T):
    K = min(K, E)
    cfg = mk_cfg(E=E, K=K)
    p = moe_lib.init_moe(jax.random.PRNGKey(E), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(T), (1, T, cfg.d_model))
    out, aux = moe_lib.moe_layer(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
