"""Optional-hypothesis shim.

`hypothesis` is a dev extra (see pyproject.toml); the tier-1 suite must
collect and run without it. Import `given/settings/st` from here instead of
from hypothesis directly: when the package is absent, `@given(...)` turns the
property test into a cleanly-skipped test instead of an ImportError at
collection time.
"""
try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any `st.xxx(...)` call; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (pip install '.[dev]')")(f)
