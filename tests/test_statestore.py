"""PrefixStore unit tests — the host-offloaded versioned prefix buffer.

Drives the store directly (no model forward, no subprocess) through the
exact access pattern `run_group` uses: ascending F reads/writes, the first
B event's `drop_device`, then F2 re-reads. The slow CP suite proves
end-to-end loss/grad equivalence; these tests pin the store's contracts:

  * offload keeps exactly ONE device-resident version during the ascending
    sweep (vs n+1 without offload) and mirrors every own-bucket to host;
  * F2 re-reads are exact on every slot chunk i can see (< i*C) — the
    seg-mask argument that lets one reassembled buffer serve all F2 chunks;
  * `_needed_buckets` follows the planner's access schedule;
  * stats (prefetches, host/device bytes) say what happened;
  * non-offload misses raise; non-KV families silently ignore offload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import statestore as ss
from repro.core.chunked_step import alg2_schedule

CFG = ModelConfig(name="tiny-store", family="dense", num_layers=2,
                  d_model=16, num_heads=2, num_kv_heads=1, head_dim=8,
                  d_ff=32, vocab_size=17, dtype="float32",
                  rope_theta=10_000.0)
C, B, N, K = 4, 2, 5, 2
CAP = ss.prefix_capacity(N, C)


def _owns(seed=0):
    rng = np.random.RandomState(seed)
    shape = (CFG.num_layers, B, C, CFG.padded_num_kv_heads,
             CFG.resolved_head_dim)
    return [{"k": jnp.asarray(rng.standard_normal(shape), jnp.float32),
             "v": jnp.asarray(rng.standard_normal(shape), jnp.float32)}
            for _ in range(N)]


def _store(offload, owns, **kw):
    access = [e[1] for e in alg2_schedule(N, K) if e[0] in ("F", "F2")]
    store = ss.PrefixStore(CFG, ss.alloc_prefix(CFG, B, CAP), N, C, K,
                           offload=offload, schedule=access, **kw)
    for i in range(N):
        nxt = ss.write_own(CFG, store.get(i), owns[i], i * C)
        store.put(i + 1, nxt, owns[i])
    return store


def test_offload_bounds_device_versions():
    owns = _owns()
    plain, off = _store(False, owns), _store(True, owns)
    assert len(plain._versions) == N + 1     # every version stays resident
    assert len(off._versions) == 1           # only the latest
    assert sorted(off._host) == list(range(N))
    # latest versions agree bit-for-bit
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 plain.get(N), off.get(N))
    # stats reflect the residency difference
    assert off.stats.offloaded and not plain.stats.offloaded
    assert off.stats.host_bytes > 0 and plain.stats.host_bytes == 0
    assert off.stats.device_bytes_peak < plain.stats.device_bytes_peak


def test_f2_rereads_exact_on_visible_slots():
    """After drop_device, the reassembled buffer matches each F2 chunk's
    original version on every slot < i*C (all it can attend to)."""
    owns = _owns(1)
    plain, off = _store(False, owns), _store(True, owns)
    off.drop_device()
    assert off._versions == {}
    keep_from = max(N - K, 0)
    for i in reversed(range(keep_from)):     # the F2 phase, in replay order
        got, want = off.get(i), plain.get(i)
        np.testing.assert_array_equal(got["k"][:, :, :i * C],
                                      want["k"][:, :, :i * C])
        np.testing.assert_array_equal(got["v"][:, :, :i * C],
                                      want["v"][:, :, :i * C])
    # one buffer serves every F2 read; each needed bucket transferred once
    assert off.stats.prefetches == len(off._needed_buckets())
    assert off.get(0) is off.get(1)


def test_needed_buckets_follow_schedule():
    owns = _owns()
    off = _store(True, owns)
    # highest F2 chunk is keep_from-1 = 2, which reads buckets j < 2
    assert off._needed_buckets() == [0, 1]
    # without a schedule the store falls back to the same alg2 bound
    off2 = ss.PrefixStore(CFG, ss.alloc_prefix(CFG, B, CAP), N, C, K,
                          offload=True)
    for i in range(N):
        off2.put(i + 1, ss.write_own(CFG, off2.get(i), owns[i], i * C),
                 owns[i])
    assert off2._needed_buckets() == off._needed_buckets()


def test_prefetch_depth_does_not_change_result():
    owns = _owns(2)
    a, b = _store(True, owns, prefetch_depth=1), \
        _store(True, owns, prefetch_depth=3)
    a.drop_device(), b.drop_device()
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                 a.get(0), b.get(0))
    assert a.stats.prefetches == b.stats.prefetches


def test_non_offload_miss_raises():
    owns = _owns()
    plain = _store(False, owns)
    with pytest.raises(KeyError):
        plain.get(N + 3)


def test_offload_ignored_for_recurrent_families():
    cfg = ModelConfig(name="tiny-store-ssm", family="ssm", num_layers=1,
                      d_model=16, num_heads=0, num_kv_heads=0, head_dim=8,
                      d_ff=0, vocab_size=17, dtype="float32",
                      rope_theta=10_000.0, ssm_state=4, ssm_head_dim=4,
                      ssm_chunk=4)
    store = ss.PrefixStore(cfg, ss.alloc_prefix(cfg, B, 0), N, C, K,
                           offload=True)
    assert not store.offload and not store.stats.offloaded
