"""shard_map EP-local MoE vs the pjit scatter layer, on a real (2,4) mesh."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models.moe_a2a import moe_layer_eplocal

cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=64,
                  num_experts=8, experts_per_token=2, capacity_factor=8.0,
                  dtype="float32")
mesh = jax.make_mesh((2, 4), ("data", "model"))
p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

ref, aux_ref = moe_lib.moe_layer(p, x, cfg)

with mesh:
    out, aux = jax.jit(lambda p, x: moe_layer_eplocal(
        p, x, cfg, mesh, ("data",)))(p, x)

np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)

# gradients flow through the shard_map region
def loss(p):
    out, aux = moe_layer_eplocal(p, x, cfg, mesh, ("data",))
    return jnp.sum(out ** 2) + aux
with mesh:
    g = jax.jit(jax.grad(loss))(p)
for k, v in g.items():
    assert float(jnp.abs(v).sum()) > 0, k
print("MOE-EPLOCAL-OK")
"""


def test_eplocal_matches_pjit_scatter():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=540)
    assert "MOE-EPLOCAL-OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]
