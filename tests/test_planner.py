"""Heterogeneous parallelism planner (core/planner.py + the ExecutionPlan
API): solver optimality vs brute force, never-worse-than-fixed property,
legacy wave reproduction, the executors' deprecation shim, world-mode
grid_search ranking, and (slow) mixed-cp executor equivalence on a forced
8-device mesh.
"""
import dataclasses
import os
import subprocess
import sys

import pytest

from repro.core import dp_balance, planner, tuning
from repro.core.chunking import construct_chunks, group_chunks
from repro.core.planner import ExecutionPlan, WavePlan
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF

CS = 2048


def units_for(lengths: dict, k: int = 1):
    g, s = group_chunks(construct_chunks(lengths, CS))
    return dp_balance.units_from_chunks(g, s, k=k, static_shapes=True)


# ---------------------------------------------------------------- solver ----
def brute_force_makespan(units, *, data: int, seq: int, k: int) -> float:
    """Independent exhaustive scorer: every ring/packed subset of the
    largest-first unit order, ring waves packed ``data`` wide at cp=seq,
    packed waves ``data*seq`` wide at cp=1, summing `planner.wave_cost`
    per wave."""
    ordered = planner._unit_order(units)
    n = len(ordered)
    best = None
    for mask in range(1 << n):
        ring = [u for j, u in enumerate(ordered) if mask >> j & 1]
        packed = [u for j, u in enumerate(ordered) if not mask >> j & 1]
        m = 0.0
        for i in range(0, len(ring), data):
            blk = ring[i:i + data]
            m += planner.wave_cost(max(u.n_chunks for u in blk), CS, k, seq)
        for i in range(0, len(packed), data * seq):
            blk = packed[i:i + data * seq]
            m += planner.wave_cost(max(u.n_chunks for u in blk), CS, k, 1)
        if best is None or m < best:
            best = m
    return best


SMALL_BATCHES = [
    {0: 8 * CS - 5, 1: 3 * CS, 2: 40, 3: 900, 4: CS // 2},
    {0: 6 * CS, 1: 6 * CS - 7, 2: 2 * CS, 3: 10, 4: 11, 5: 12},
    {0: 4 * CS, 1: 100},
    {0: 300, 1: 200, 2: 100},                    # no tail at all
    {0: 8 * CS - 1},                             # tail only
]


@pytest.mark.parametrize("data,seq", [(1, 2), (2, 2), (1, 4), (2, 4)])
def test_solver_matches_brute_force(data, seq):
    for k in (1, 2):
        for lengths in SMALL_BATCHES:
            units = units_for(lengths, k=k)
            assert len(units) <= planner.EXACT_UNITS
            _, got = planner.solve_waves(units, data=data, seq=seq, k=k,
                                         chunk_size=CS)
            want = brute_force_makespan(units, data=data, seq=seq, k=k)
            assert got == pytest.approx(want, rel=1e-9), (lengths, k)


def test_prefix_scan_never_worse_than_fixed_and_bounded_by_exact():
    """The at-scale sorted-prefix scan contains both fixed extremes, so it
    is never worse than either; the exact solve is never worse than the
    scan."""
    for lengths in SMALL_BATCHES:
        units = units_for(lengths)
        _, exact = planner.solve_waves(units, data=2, seq=2, chunk_size=CS)
        _, scan = planner.solve_waves(units, data=2, seq=2, chunk_size=CS,
                                      exact_limit=0)
        _, fix1 = planner.fixed_waves(units, world=4, cp=1, chunk_size=CS)
        _, fix2 = planner.fixed_waves(units, world=4, cp=2, chunk_size=CS)
        assert exact <= scan + 1e-9
        assert scan <= min(fix1, fix2) + 1e-9


def test_solved_never_worse_than_any_fixed_config_paper_cdf():
    """Property over paper-CDF samples at world 8: the heterogeneous solve
    beats (or ties) EVERY fixed cp config — large instances go through the
    prefix scan, so this pins the at-scale guarantee."""
    for seed in range(5):
        s = LongTailSampler(PAPER_EVAL_CDF, seed=seed, max_len=262_144)
        lengths = dict(enumerate(s.sample_batch_lengths(256)))
        for k in (1, 2):
            units = units_for(lengths, k=k)
            best = planner.solve_world(units, world=8, k=k, chunk_size=CS)
            assert best is not None
            _, solved, shape = best
            for cp in (1, 2, 4, 8):
                _, fixed = planner.fixed_waves(units, world=8, cp=cp, k=k,
                                               chunk_size=CS)
                assert solved <= fixed + 1e-9, (seed, k, cp, shape)


def test_wave_cost_pp1_is_ticks_plus_comm():
    """At pp=1 the rotation collapses: N forwards + N (2x) backwards +
    (N - K) recomputes, each one tick, plus the ring comm term."""
    for n, k, cp in [(4, 1, 1), (4, 2, 2), (7, 2, 4), (1, 1, 2)]:
        ticks = 3 * n + max(0, n - k)
        want = (ticks * planner.tick_cost(n, CS, cp)
                + planner.ring_comm_cost(n, CS, cp, k=k))
        assert planner.wave_cost(n, CS, k, cp) == pytest.approx(want)


# ------------------------------------------------- legacy reproduction ------
def test_legacy_policies_reproduce_dp_balance_waves():
    """policy="lpt"/"round_robin" must form byte-identical waves to the
    pre-planner `plan_assignment` + `wave_schedule` path (the deprecation
    shim rides on this)."""
    lengths = SMALL_BATCHES[0]
    for policy in ("lpt", "round_robin"):
        for seq, cp_threshold in [(1, 0), (2, 0), (2, 3 * CS), (4, 1 << 30)]:
            units = dp_balance.units_from_chunks(
                *group_chunks(construct_chunks(lengths, CS)), k=1,
                static_shapes=True, cp=seq, cp_threshold=cp_threshold)
            old_waves, _ = dp_balance.wave_schedule(
                dp_balance.plan_assignment(units, 2, policy=policy))
            plan = planner.plan_lengths(
                lengths, CS, {"data": 2, "seq": seq}, k=1, policy=policy,
                cp_threshold=cp_threshold)
            assert len(plan.waves) == len(old_waves)
            for w, old in zip(plan.waves, old_waves):
                assert [u and (u.kind, u.key) for u in w.slots] == \
                    [u and (u.kind, u.key) for u in old]
                ring = seq > 1 and any(u is not None and u.ring for u in old)
                assert w.cp == (seq if ring else 1)


def test_plan_batch_surface():
    lengths = {0: 4 * CS, 1: 300, 2: 400}
    plan = planner.plan_lengths(lengths, CS, {"data": 2, "seq": 2}, k=2)
    assert plan.mesh_shape == {"data": 2, "pipe": 1, "seq": 2}
    assert plan.world_size == 4
    assert plan.chunk_size == CS and plan.k == 2
    assert plan.wave_cps == [w.cp for w in plan.waves]
    assert all(cp in (1, 2) for cp in plan.wave_cps)
    assert plan.predicted_makespan == pytest.approx(
        planner.plan_makespan(plan.waves, CS, 2))
    assert "ExecutionPlan[solve]" in plan.describe()
    # every unit lands in exactly one slot
    keys = [(u.kind, u.key) for w in plan.waves for u in w.slots
            if u is not None]
    assert sorted(keys) == sorted((u.kind, u.key) for u in units_for(
        lengths, k=2))


# ------------------------------------------------------ deprecation shim ----
def test_legacy_kwargs_emit_deprecation_warning():
    """Old executor signature still works, under DeprecationWarning. An
    empty batch exercises the shim without touching a model."""
    from repro.core import chunked_step
    with pytest.warns(DeprecationWarning, match="ExecutionPlan"):
        loss, grads, stats = chunked_step.run_batch(None, None, [], [], k=1)
    assert float(loss) == 0.0 and grads is None

    with pytest.warns(DeprecationWarning):
        chunked_step.run_batch(None, None, [], [], plan_policy="lpt")
    with pytest.warns(DeprecationWarning):
        chunked_step.run_batch(None, None, [], [], cp_threshold=4096)

    # the new calling convention is warning-free
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        loss, grads, stats = chunked_step.run_batch(None, None, ([], []))
    assert float(loss) == 0.0 and grads is None


# ----------------------------------------------------- world-mode tuner -----
def test_grid_search_world_mode_ranked():
    s = LongTailSampler(PAPER_EVAL_CDF, seed=0, max_len=262_144)
    batches = [dict(enumerate(s.sample_batch_lengths(256)))
               for _ in range(2)]
    r = tuning.grid_search(batches, pp=1, memory_token_budget=16384,
                           chunk_sizes=(2048, 4096), ks=(1, 2),
                           world_size=8, include_heterogeneous=True)
    assert r.ranked and all(isinstance(c, tuning.LaunchConfig)
                            for c in r.ranked)
    spans = [c.makespan for c in r.ranked]
    assert spans == sorted(spans)
    assert (r.chunk_size, r.k) == (r.ranked[0].chunk_size, r.ranked[0].k)
    assert r.score == r.ranked[0].makespan
    # fixed table keyed (C, K, cp); every fixed entry gated by the budget
    assert all(len(key) == 3 for key in r.table)
    assert all(c.k * c.chunk_size <= 16384 for c in r.ranked)
    het = [c for c in r.ranked if c.heterogeneous]
    fixed = [c for c in r.ranked if not c.heterogeneous]
    assert het and fixed
    # solver guarantee carried through the tuner: best het <= best fixed
    assert het[0].makespan <= fixed[0].makespan + 1e-9
    assert all(c.dp * c.pp * c.cp == 8 for c in r.ranked)


def test_grid_search_legacy_mode_unchanged_plus_ranked():
    s = LongTailSampler(PAPER_EVAL_CDF, seed=1, max_len=65_536)
    batches = [dict(enumerate(s.sample_batch_lengths(64)))]
    r = tuning.grid_search(batches, pp=1, memory_token_budget=8192)
    assert all(len(key) == 2 for key in r.table)     # legacy (C, K) keys
    assert r.k == 1                                  # pp=1 forces K=1
    assert [c.makespan for c in r.ranked] == sorted(r.table.values())
    assert r.ranked[0].chunk_size == r.chunk_size
    assert all(c.dp == 1 and c.cp == 1 for c in r.ranked)


# ----------------------------------------- mixed-cp executor equivalence ----
MIXED_CP = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import chunking, chunked_step, dp_balance, planner
from repro.core.planner import ExecutionPlan, WavePlan
from repro.models import api
from repro.launch import mesh as mesh_lib

cfg = ModelConfig(name="plan-gqa", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=61, dtype="float32", rope_theta=10_000.0,
                  attn_backend="pallas_interpret")
C = 16
LENGTHS = {0: 4 * C - 3, 1: 2 * C, 2: 9, 3: 5, 4: 12, 5: 7, 6: 30, 7: 13}

rng = np.random.RandomState(0)
seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
        for i, l in LENGTHS.items()}
groups, standalone = chunking.group_chunks(
    chunking.construct_chunks(LENGTHS, C))
gb = [[chunking.materialize_chunk(c, seqs) for c in g]
      for g in groups.values()]
sb = [chunking.materialize_chunk(c, seqs) for c in standalone]
params = api.init_params(cfg, jax.random.PRNGKey(0))

to_dev = lambda m: {k: jnp.asarray(v) for k, v in m.items()}
ref_loss, ref_grads, _ = chunked_step.run_batch(
    cfg, params, ([[to_dev(b) for b in g] for g in gb],
                  [to_dev(b) for b in sb]))

def check(tag, got):
    loss, grads, stats = got
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5,
                               err_msg=tag)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6, err_msg=tag),
        grads, ref_grads)
    return stats

# --- hand-built MIXED plan on a (data=4 x seq=2) mesh: the multi-chunk
# units ride cp=2 ring waves (width 4), the shorts pack one cp=1 wave
# widened to all 8 device slots
mesh = mesh_lib.make_train_mesh(4, 1, 2)
units = dp_balance.units_from_materialized(gb, sb, k=1, static_shapes=True)
ring_units = sorted([u for u in units if u.n_chunks > 1],
                    key=lambda u: -u.n_chunks)
pack_units = [u for u in units if u.n_chunks == 1]
assert ring_units and pack_units, (len(ring_units), len(pack_units))
waves = ([WavePlan(cp=2, slots=tuple(ring_units[i:i + 4])
                   + (None,) * (4 - len(ring_units[i:i + 4])))
          for i in range(0, len(ring_units), 4)]
         + [WavePlan(cp=1, slots=tuple(pack_units[i:i + 8])
                     + (None,) * (8 - len(pack_units[i:i + 8])))
            for i in range(0, len(pack_units), 8)])
plan = ExecutionPlan(data=4, pipe=1, seq=2, chunk_size=C, k=1, waves=waves,
                     mesh=mesh)
assert plan.heterogeneous
got = chunked_step.run_batch(cfg, params, (gb, sb), plan)
stats = check("mixed-cp", got)
assert set(stats.wave_cps) == {1, 2}, stats.wave_cps
assert stats.ring_steps > 0

# --- the solved plan (whatever split it picks) is equivalent too, through
# the unified run_batch front door
for policy in ("solve", "lpt"):
    p2 = planner.plan_batch(gb, sb, mesh, k=1, policy=policy)
    check(f"policy-{policy}", chunked_step.run_batch(cfg, params, (gb, sb),
                                                     p2))

# --- all three executors accept an ExecutionPlan directly
from repro.distributed import context_parallel, pipeline
check("cp-direct", context_parallel.run_batch_cp(cfg, params, (gb, sb),
                                                 plan))
mesh2d = mesh_lib.make_train_mesh(2, 2, 2)
p3 = planner.plan_batch(gb, sb, mesh2d, k=2, policy="solve")
lo, gr, st = pipeline.run_batch_pipelined(cfg, params, (gb, sb), p3)
np.testing.assert_allclose(float(lo), float(ref_loss), rtol=1e-5)
assert st.wave_cps, "pipeline must report per-wave cps"

# K < N recompute through a mixed plan
plan_k = ExecutionPlan(data=4, pipe=1, seq=2, chunk_size=C, k=1,
                       waves=waves, mesh=mesh)
got = chunked_step.run_batch(cfg, params, (gb, sb), plan_k)
stats = check("mixed-cp-k1", got)
assert stats.recompute_calls > 0
print("PLANNER-MIXED-CP-OK")
"""


@pytest.mark.slow
def test_mixed_cp_plan_matches_single_device():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", MIXED_CP], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "PLANNER-MIXED-CP-OK" in r.stdout, r.stdout + "\n" + r.stderr


# ------------------------------------------- ring cost unification ----------
def test_ring_cost_constants_single_home():
    """Satellite of the ring-overlap PR: the planner re-exports dp_balance's
    ring cost constants — ONE home, so the solver and the wave packer can
    never price a hop differently."""
    assert planner.RING_LATENCY is dp_balance.RING_LATENCY
    assert planner.RING_BW is dp_balance.RING_BW


def test_ring_comm_cost_planner_agrees_with_dp_balance():
    for n, cp, k in [(1, 2, 1), (4, 2, 1), (7, 4, 2), (74, 8, 2), (3, 1, 1)]:
        assert planner.ring_comm_cost(n, CS, cp, k=k) == pytest.approx(
            dp_balance.ring_comm_cost(n, CS, cp, k=k))


def test_overlap_discounts_comm_but_never_below_exposed_floor():
    """overlap=True hides the K/V prefetch hops under the per-hop kernel
    window; the dk/dv accumulator's final hops home stay fully exposed, so
    the overlapped cost is bounded below by exposed_hops * comm_per_hop and
    above by the serial cost."""
    for n, cp, k in [(4, 2, 1), (7, 4, 2), (74, 8, 2)]:
        serial = planner.ring_comm_cost(n, CS, cp, k=k)
        over = planner.ring_comm_cost(n, CS, cp, k=k, overlap=True)
        rec = max(n - max(1, k), 0)
        total = dp_balance.ring_step_count(n, cp, k=k)
        hidden = dp_balance.overlapped_ring_hops(n + rec, n, cp)
        exposed = total - hidden
        assert exposed == n          # one accumulator hop home per backward
        assert over <= serial + 1e-9
        assert over >= exposed * (serial / total) - 1e-9
    # cp=1: no ring, no cost either way
    assert planner.ring_comm_cost(4, CS, 1, overlap=True) == 0.0


def test_wave_cost_overlap_kwarg_threads_through():
    for n, k, cp in [(4, 2, 2), (7, 2, 4)]:
        ticks = 3 * n + max(0, n - k)
        want = (ticks * planner.tick_cost(n, CS, cp)
                + planner.ring_comm_cost(n, CS, cp, k=k, overlap=True))
        got = planner.wave_cost(n, CS, k, cp, overlap=True)
        assert got == pytest.approx(want)
        assert got <= planner.wave_cost(n, CS, k, cp) + 1e-9


# ------------------------------------------- StateStore offload plan --------
def test_prefix_access_order_matches_alg2_schedule():
    """The planner's analytic prefetch schedule must equal the read order
    the executor derives from alg2_schedule itself (statestore.PrefixStore
    consumes exactly this)."""
    from repro.core.chunked_step import alg2_schedule
    for n in (1, 2, 3, 5, 8, 74):
        for k in (1, 2, 4):
            want = [e[1] for e in alg2_schedule(n, k)
                    if e[0] in ("F", "F2")]
            assert planner.prefix_access_order(n, k) == want, (n, k)


def test_statestore_device_bytes_offload_bounds():
    """Offload decouples device residency from the VERSION count: without
    offload the store holds n+1 capacity buffers (quadratic-ish in n, since
    the pow2-bucketed capacity itself grows with n); with offload it holds
    ~(k+2) buffers + the prefetch window, so the win factor approaches
    (n+1)/(k+2) and GROWS with sequence length."""
    per_tok = 4096.0
    for cp in (1, 8):
        resident, off = {}, {}
        for n in (8, 74):
            resident[n] = planner.statestore_device_bytes(
                n, CS, cp, n_layers=8, bytes_per_token=per_tok, k=2,
                offload=False)
            off[n] = planner.statestore_device_bytes(
                n, CS, cp, n_layers=8, bytes_per_token=per_tok, k=2,
                offload=True, prefetch_depth=2)
            assert off[n] < resident[n]
        assert resident[74] / off[74] > resident[8] / off[8]
        # paper-CDF tail group (74 chunks, k=2): win approaches 75/4
        assert resident[74] / off[74] > 15


def test_execution_plan_carries_overlap_and_offload():
    plan = planner.plan_lengths({0: 4 * CS}, CS, {"data": 1, "seq": 2}, k=1)
    assert plan.ring_overlap is True          # default: overlap on
    assert plan.offload_statestore is False   # default: no offload
    assert plan.prefetch_depth == 2
    lengths = {0: 4 * CS, 1: 300}
    from repro.core.chunking import construct_chunks, group_chunks
    from repro.core.chunking import materialize_chunk  # noqa: F401
    g, s = group_chunks(construct_chunks(lengths, CS))
    # plan_batch threads the knobs into the plan (shape-dict mesh)
    p = planner.plan_batch([], [], {"data": 1, "seq": 1}, k=1,
                           ring_overlap=False, offload_statestore=True,
                           prefetch_depth=3)
    assert (p.ring_overlap, p.offload_statestore, p.prefetch_depth) == \
        (False, True, 3)
