"""Per-architecture smoke tests: REDUCED variant of each assigned config runs
one forward and one train step on CPU; output shapes and finiteness asserted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import api, decode


def make_batch(cfg, B=2, T=32, key=None):
    key = key or jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size),
        "segment_ids": jnp.ones((B, T), jnp.int32),
    }
    base = jnp.arange(T, dtype=jnp.int32)[None].repeat(B, 0)
    batch["positions"] = (jnp.stack([base] * 3, -1) if cfg.mrope else base)
    if cfg.family == "audio":
        batch["encoder_embeds"] = jax.random.normal(
            ks[1], (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_smoke(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers <= 8 and cfg.d_model <= 512 and cfg.num_experts <= 4
    params = api.init_params(cfg, jax.random.PRNGKey(1), max_seq=64)
    batch = make_batch(cfg, B=2, T=32)
    logits, state, aux = jax.jit(
        lambda p, b: api.forward(cfg, p, b))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    """One SGD step on the summed token loss; params move, loss finite."""
    cfg = ARCHS[arch].reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(1), max_seq=64)
    batch = make_batch(cfg, B=2, T=32)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, _, aux = api.forward(cfg, p, batch)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        return nll.mean() + aux["moe_aux"]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert gnorm > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(1), max_seq=64)
    B, S = 2, 16
    cache = decode.init_decode_cache(cfg, B, S)
    if cfg.family == "audio":
        enc = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        cache = decode.prefill_audio_cross(cfg, params, cache, enc)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: decode.decode_step(cfg, p, c, t, 3))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["qwen2.5-7b", "qwen2.5-32b", "qwen2.5-72b"])
def test_paper_arch_smoke(arch):
    """The paper's own Qwen2.5 sizes (registry.PAPER_ARCHS) also run."""
    from repro.configs.registry import get_arch
    cfg = get_arch(arch).reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, B=1, T=16)
    logits, _, _ = api.forward(cfg, params, batch)
    assert logits.shape == (1, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
