"""Substrate tests: optimizers, data sampler, checkpointing, packing utils."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.data.synthetic import (LMSYS_CDF, PAPER_EVAL_CDF, LongTailSampler)
from repro.optim import adafactor, adamw


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([3.0, -2.0]), "stack": jnp.ones((4, 8, 3))}
    opt = adamw.adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["stack"] ** 2)

    step = jax.jit(lambda p, o: adamw.adamw_update(
        p, jax.grad(loss)(p), o, lr=5e-2, weight_decay=0.0))
    for _ in range(200):
        params, opt, gnorm = step(params, opt)
    assert float(loss(params)) < 1e-2
    assert int(opt["step"]) == 200


def test_adamw_layer_stacked_matches_flat():
    """lax.map slicing over the leading dim must not change the math."""
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(3, 8, 4), jnp.float32)
    p = jnp.asarray(rng.randn(3, 8, 4), jnp.float32)
    o1 = adamw.adamw_init({"x": p})
    stacked, _, _ = adamw.adamw_update({"x": p}, {"x": g}, o1, lr=1e-2,
                                       grad_clip=0.0)
    # same update per slice, computed unstacked
    outs = []
    for i in range(3):
        oi = adamw.adamw_init({"x": p[i]})
        s, _, _ = adamw.adamw_update({"x": p[i]}, {"x": g[i]}, oi, lr=1e-2,
                                     grad_clip=0.0)
        outs.append(s["x"])
    np.testing.assert_allclose(np.asarray(stacked["x"]),
                               np.stack(outs), rtol=1e-5, atol=1e-8)


def test_adafactor_converges_and_is_factored():
    params = {"w": jnp.ones((16, 8)) * 3.0}
    opt = adafactor.adafactor_init(params)
    assert opt["slots"]["w"]["vr"].shape == (16,)
    assert opt["slots"]["w"]["vc"].shape == (8,)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    step = jax.jit(lambda p, o: adafactor.adafactor_update(
        p, jax.grad(loss)(p), o, lr=5e-2))
    for _ in range(300):
        params, opt = step(params, opt)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule():
    lr0 = float(adamw.cosine_schedule(0, base_lr=1.0, warmup_steps=10,
                                      total_steps=100))
    lrw = float(adamw.cosine_schedule(10, base_lr=1.0, warmup_steps=10,
                                      total_steps=100))
    lre = float(adamw.cosine_schedule(100, base_lr=1.0, warmup_steps=10,
                                      total_steps=100))
    assert lr0 == 0.0 and abs(lrw - 1.0) < 1e-6 and lre <= 0.11


@pytest.mark.parametrize("cdf,targets", [
    (PAPER_EVAL_CDF, {1024: 0.9817, 32768: 0.9992}),
    (LMSYS_CDF, {1024: 0.90499, 4096: 0.99539}),
])
def test_longtail_sampler_matches_paper_cdf(cdf, targets):
    s = LongTailSampler(cdf, seed=0)
    stats = s.bucket_stats(30_000)
    for ub, t in targets.items():
        assert abs(stats[ub] - t) < 0.01, (ub, stats[ub], t)


def test_sampler_context_cutoff():
    s = LongTailSampler(PAPER_EVAL_CDF, seed=1, max_len=32768)
    assert max(s.sample_batch_lengths(5000)) <= 32768


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.array(7, jnp.int32)}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt.msgpack")
        save_checkpoint(path, tree, step=42)
        restored, step = restore_checkpoint(path, tree)
    assert step == 42
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, restored)
    assert restored["b"]["c"].dtype == jnp.bfloat16


@given(st.integers(1, 40), st.integers(8, 64))
@settings(max_examples=30, deadline=None)
def test_sampler_batch_shapes(n, minlen):
    s = LongTailSampler(PAPER_EVAL_CDF, min_len=minlen, seed=3, max_len=4096)
    seqs, lengths = s.sample_batch(n, vocab_size=100)
    assert set(seqs) == set(range(n))
    for i, arr in seqs.items():
        assert len(arr) == lengths[i] >= minlen
        assert arr.dtype == np.int32 and (arr > 0).all() and (arr < 100).all()
