"""Context-parallel ("seq" axis) ring attention executor: numerical
equivalence to the single-device ChunkFlow scheduler across the full mask
contract (prefix 0/C/3C, packed segments, sliding window + softcap, GQA),
cp_threshold ring gating, and the 3D dp x pipe x seq composition.

Subprocess tests because XLA_FLAGS must be set before jax initializes (and
the rest of the suite must keep seeing 1 device), like test_pipeline2d.py.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import chunking, chunked_step
from repro.models import api
from repro.launch import mesh as mesh_lib

# GQA (4 query / 2 kv heads) is the base; the "gemma2" variant adds
# attention softcap + sliding-window local/global alternation.
BASE = dict(family="dense", num_layers=2, d_model=32, num_heads=4,
            num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=61,
            dtype="float32", rope_theta=10_000.0,
            attn_backend="pallas_interpret")
CFGS = {
    "gqa": ModelConfig(name="cp-gqa", **BASE),
    "gemma2": ModelConfig(name="cp-gemma2", attn_softcap=30.0,
                          sliding_window=24, local_global_alternate=True,
                          **BASE),
}
C = 16


def make_batch(cfg, lengths, seed=0):
    rng = np.random.RandomState(seed)
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in lengths.items()}
    chunks = chunking.construct_chunks(lengths, C)
    groups, standalone = chunking.group_chunks(chunks)
    gb = [[chunking.materialize_chunk(c, seqs) for c in g]
          for g in groups.values()]
    sb = [chunking.materialize_chunk(c, seqs) for c in standalone]
    return gb, sb


def single_device_ref(cfg, params, gb, sb, k):
    gb_d = [[{k2: jnp.asarray(v) for k2, v in b.items()} for b in g]
            for g in gb]
    sb_d = [{k2: jnp.asarray(v) for k2, v in b.items()} for b in sb]
    return chunked_step.run_batch(cfg, params, gb_d, sb_d, k=k)


def check(tag, got, want):
    loss, grads, stats = got
    ref_loss, ref_grads, _ = want
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5,
                               err_msg=str(tag))
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6,
            err_msg=str(tag)),
        grads, ref_grads)
    return stats
"""

EQUIVALENCE = (_PRELUDE % 4) + r"""
# prefix coverage: a 4-chunk group exercises StateStore prefixes C..3C
# (capacity 4C); standalone packed chunks exercise prefix 0 + segment
# masking; a 2-chunk group exercises the smallest capacity bucket.
LENGTHS = {0: 4 * C - 3, 1: 2 * C, 2: 9, 3: 5, 4: 12, 5: 7}

for name, cfg in CFGS.items():
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    gb, sb = make_batch(cfg, LENGTHS)
    ref = single_device_ref(cfg, params, gb, sb, 1)
    for cp in (2, 4):
        mesh = mesh_lib.make_train_mesh(1, 1, cp)
        got = chunked_step.run_batch(cfg, params, gb, sb, k=1, mesh=mesh)
        stats = check((name, cp), got, ref)
        assert stats.ring_steps > 0, (name, cp)

# K < N recompute on the ring + dp x cp composition
cfg = CFGS["gqa"]
params = api.init_params(cfg, jax.random.PRNGKey(1))
gb, sb = make_batch(cfg, {0: 5 * C - 3, 1: 3 * C, 2: 9, 3: 30})
for k in (1, 2):
    ref = single_device_ref(cfg, params, gb, sb, k)
    got = chunked_step.run_batch(cfg, params, gb, sb, k=k,
                                 mesh=mesh_lib.make_train_mesh(1, 1, 2))
    stats = check(("recompute", k), got, ref)
    if k == 1:
        assert stats.recompute_calls > 0
    got = chunked_step.run_batch(cfg, params, gb, sb, k=k,
                                 mesh=mesh_lib.make_train_mesh(2, 1, 2))
    check(("dp2cp2", k), got, ref)

# cp_threshold: long-tail units ride the ring, short ones replicate; both
# regimes (and the all-off extreme) stay numerically equivalent
mesh = mesh_lib.make_train_mesh(1, 1, 2)
ref = single_device_ref(cfg, params, gb, sb, 1)
got = chunked_step.run_batch(cfg, params, gb, sb, k=1, mesh=mesh,
                             cp_threshold=3 * C)
stats = check(("threshold",), got, ref)
assert stats.ring_steps > 0
got = chunked_step.run_batch(cfg, params, gb, sb, k=1, mesh=mesh,
                             cp_threshold=1 << 30)
stats = check(("threshold-off",), got, ref)
assert stats.ring_steps == 0

# ring-hop accounting matches the analytic count
from repro.core.dp_balance import ring_step_count
gb1, sb1 = make_batch(cfg, {0: 4 * C})        # one 4-chunk group, nothing else
ref = single_device_ref(cfg, params, gb1, sb1, 2)
got = chunked_step.run_batch(cfg, params, gb1, sb1, k=2, mesh=mesh)
stats = check(("hops",), got, ref)
assert stats.ring_steps == ring_step_count(4, 2, k=2,
                                           n_layers=cfg.num_layers)

# ring overlap: double-buffered (default) vs serial ring must match the
# single-device reference identically — the overlap only reorders WHEN the
# ppermute is issued, never what is computed — and overlapped-hop
# accounting matches dp_balance.overlapped_ring_hops (> 0 iff overlap on)
import warnings
from repro.core import planner
warnings.simplefilter("ignore", DeprecationWarning)
ref = single_device_ref(cfg, params, gb, sb, 2)
mesh = mesh_lib.make_train_mesh(1, 1, 2)
hop_stats = {}
for overlap in (True, False):
    plan = planner.plan_batch(gb, sb, mesh, k=2, policy="lpt",
                              ring_overlap=overlap)
    got = chunked_step.run_batch(cfg, params, (gb, sb), plan)
    stats = check(("overlap", overlap), got, ref)
    hop_stats[overlap] = stats
assert hop_stats[False].overlapped_hops == 0
assert 0 < hop_stats[True].overlapped_hops < hop_stats[True].ring_steps
assert hop_stats[True].ring_steps == hop_stats[False].ring_steps

# host-offloaded StateStore under the ring: exact to the same tolerance,
# strictly smaller store-held device residency, prefetches observed
plan = planner.plan_batch(gb, sb, mesh, k=2, policy="lpt",
                          offload_statestore=True)
got = chunked_step.run_batch(cfg, params, (gb, sb), plan)
st_off = check(("offload",), got, ref)
assert st_off.statestore_prefetches > 0
assert st_off.offloaded_statestore_bytes > 0
st_on = hop_stats[True]
assert st_off.resident_statestore_bytes < st_on.resident_statestore_bytes

# overlap + offload together, through the solver policy too
plan = planner.plan_batch(gb, sb, mesh, k=2, offload_statestore=True)
check(("solve-overlap-offload",), got, ref)
print("CP-EQUIVALENCE-OK")
"""

COMPOSITION = (_PRELUDE % 8) + r"""
# full 3D mesh: dp=2 x pp=2 x cp=2 (8 devices) vs single device, incl.
# K < N recompute and a mixed-length stream with standalone chunks
cfg = ModelConfig(name="cp-3d", **dict(BASE, num_layers=4))
params = api.init_params(cfg, jax.random.PRNGKey(0))
gb, sb = make_batch(cfg, {0: 4 * C - 3, 1: 2 * C, 2: 9, 3: 5, 4: 12})
mesh = mesh_lib.make_train_mesh(2, 2, 2)
for k in (1, 2):
    ref = single_device_ref(cfg, params, gb, sb, k)
    got = chunked_step.run_batch(cfg, params, gb, sb, k=k, mesh=mesh)
    stats = check(("3d", k), got, ref)
    assert stats.ring_steps > 0, k

# end-to-end train.py flag composition (--dp 2 --pp 2 --cp 2): one step
# must run and log a finite loss
from repro.launch import train as train_mod
train_mod.main(["--arch", "granite-3-8b", "--reduced", "--steps", "1",
                "--chunk-size", str(C), "--max-len", "48", "--batch", "4",
                "--dp", "2", "--pp", "2", "--cp", "2", "--prefetch", "0"])
print("CP-COMPOSITION-OK")
"""


def _run(script):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True,
                          cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))


def test_cp_matches_single_device():
    r = _run(EQUIVALENCE)
    assert "CP-EQUIVALENCE-OK" in r.stdout, r.stdout + "\n" + r.stderr


def test_cp_composes_with_dp_and_pp():
    r = _run(COMPOSITION)
    assert "CP-COMPOSITION-OK" in r.stdout, r.stdout + "\n" + r.stderr
