"""The CI perf-regression gate's failure modes, both directions:

  * a GATED benchmark missing from --current under --require-all (the bench
    didn't run / didn't emit) fails the build;
  * an orphan BENCH_*.json in --current that the GATED registry doesn't
    know (new benchmark, no committed baseline) fails under --require-all
    with the register + --update hint, and --update adopts it into the
    baseline dir.

Pure-host: drives benchmarks.check_regression.main() on tmp dirs.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import check_regression as cr  # noqa: E402


def _write(path, payload):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f)


@pytest.fixture
def dirs(tmp_path):
    cur = tmp_path / "bench"
    base = tmp_path / "baselines"
    cur.mkdir()
    base.mkdir()
    # a complete, passing GATED population in both dirs
    for name in cr.GATED:
        _write(str(cur / name), {"gate": {"m": 1.0}})
        _write(str(base / name), {"gate": {"m": 1.0}})
    return str(cur), str(base)


def _main(cur, base, *extra):
    return cr.main(["--current", cur, "--baseline", base, *extra])


def test_complete_population_passes(dirs, capsys):
    cur, base = dirs
    assert _main(cur, base, "--require-all") == 0
    assert "perf gate OK" in capsys.readouterr().out


def test_missing_current_fails_require_all(dirs, capsys):
    """Direction 1: a gated benchmark that did not run/emit in CI."""
    cur, base = dirs
    victim = sorted(cr.GATED)[0]
    os.remove(os.path.join(cur, victim))
    assert _main(cur, base, "--require-all") == 1
    assert "did not run" in capsys.readouterr().err
    # local mode (no --require-all) skips instead
    assert _main(cur, base) == 0


def test_gated_without_baseline_fails_with_update_hint(dirs, capsys):
    """A registered benchmark whose baseline was never committed."""
    cur, base = dirs
    victim = sorted(cr.GATED)[0]
    os.remove(os.path.join(base, victim))
    assert _main(cur, base, "--require-all") == 1
    assert "--update" in capsys.readouterr().err


def test_orphan_fails_require_all_with_hint(dirs, capsys):
    """Direction 2: a benchmark that emits in CI but is not in GATED."""
    cur, base = dirs
    _write(os.path.join(cur, "BENCH_newthing.json"), {"gate": {"m": 2.0}})
    assert _main(cur, base, "--require-all") == 1
    err = capsys.readouterr().err
    assert "BENCH_newthing.json" in err
    assert "--update" in err and "GATED" in err
    # without --require-all: warn-only, exit 0 (local single-bench runs)
    assert _main(cur, base) == 0
    assert "[orphan] BENCH_newthing.json" in capsys.readouterr().out


def test_update_adopts_orphans(dirs):
    cur, base = dirs
    _write(os.path.join(cur, "BENCH_newthing.json"), {"gate": {"m": 2.0}})
    assert _main(cur, base, "--update") == 0
    assert os.path.exists(os.path.join(base, "BENCH_newthing.json"))


def test_regression_still_fails(dirs, capsys):
    """The original purpose survives the orphan scan: a >threshold gated
    increase fails."""
    cur, base = dirs
    victim = "BENCH_pipeline.json"        # gated on gate.*
    _write(os.path.join(cur, victim), {"gate": {"m": 2.0}})
    assert _main(cur, base, "--require-all") == 1
    assert "regression" in capsys.readouterr().err
