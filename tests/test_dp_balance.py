"""DP-balance planner invariants + DP-vs-single-device training equivalence.

The planner tests are pure host logic (fast lane). The execution equivalence
test runs in a subprocess with 4 forced CPU devices (XLA_FLAGS must be set
before jax initializes), like test_pipeline_exec.py.
"""
import os
import random
import subprocess
import sys

import numpy as np
import pytest

from repro.core import dp_balance
from repro.core.chunking import construct_chunks, group_chunks
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF


def sample_units(seed=0, n=128, chunk_size=1024, k=2):
    s = LongTailSampler(PAPER_EVAL_CDF, seed=seed, max_len=65_536)
    lengths = dict(enumerate(s.sample_batch_lengths(n)))
    groups, standalone = group_chunks(construct_chunks(lengths, chunk_size))
    return dp_balance.units_from_chunks(groups, standalone, k=k)


# ------------------------------------------------------------ cost model ----
def test_cost_model_monotone_and_quadratic():
    w1 = dp_balance.chunk_token_work(100, 0)
    w2 = dp_balance.chunk_token_work(200, 0)
    assert w2 > w1
    # deeper prefix -> strictly more attention work, same tokens
    assert (dp_balance.chunk_token_work(100, 4096)
            > dp_balance.chunk_token_work(100, 0))
    # a packed chunk of two 50-token segments does less attention work than
    # one 100-token segment (2*50^2 < 100^2)
    packed = dp_balance.chunk_token_work(100, 0, seg_lengths=[50, 50])
    single = dp_balance.chunk_token_work(100, 0, seg_lengths=[100])
    assert packed < single


def test_unit_work_counts_recompute():
    # 4 chunks, k=1: 3 recomputes; k=4: none
    w = [1.0, 1.0, 1.0, 1.0]
    assert dp_balance.unit_work(w, k=1) == pytest.approx(12.0 + 3.0)
    assert dp_balance.unit_work(w, k=4) == pytest.approx(12.0)


def test_cp_cost_model():
    """A ring-eligible unit acts as one logical rank at 1/cp cost; units
    under cp_threshold keep full cost (they replicate over "seq")."""
    lengths = {0: 8 * 1024, 1: 5 * 1024 - 7, 2: 3 * 1024, 3: 2 * 1024 - 1,
               4: 900, 5: 500, 6: 80}
    groups, standalone = group_chunks(construct_chunks(lengths, 1024))
    base = dp_balance.units_from_chunks(groups, standalone, k=2)
    cp4 = dp_balance.units_from_chunks(groups, standalone, k=2, cp=4)
    assert all(u.ring for u in cp4)
    for u0, u4 in zip(base, cp4):
        assert u4.work == pytest.approx(u0.work / 4)
    # threshold: only units spanning >= 4 chunks ride the ring
    thr = dp_balance.units_from_chunks(groups, standalone, k=2, cp=4,
                                       cp_threshold=4 * 1024)
    assert any(u.ring for u in thr) and any(not u.ring for u in thr)
    for u0, ut in zip(base, thr):
        want = u0.work / 4 if u0.n_chunks >= 4 else u0.work
        assert ut.work == pytest.approx(want)
        assert ut.ring == (u0.n_chunks >= 4)
    # materialized-batch units agree with chunk units on the cp adjustment
    assert dp_balance.cp_eligible(4, 1024, 4, 4096)
    assert not dp_balance.cp_eligible(3, 1024, 4, 4096)
    assert not dp_balance.cp_eligible(8, 1024, 1, 0)       # cp=1: never


def test_ring_step_count():
    """cp-1 K/V rotation hops per forward (incl. recompute forwards), cp per
    backward (the dk/dv accumulator takes one extra hop home)."""
    assert dp_balance.ring_step_count(1, 4) == (4 - 1) + 4
    # 4 chunks, k=1 -> 3 recomputes: hops = (cp-1)*(4+3) + cp*4
    assert dp_balance.ring_step_count(4, 2, k=1) == 1 * 7 + 2 * 4
    assert dp_balance.ring_step_count(4, 2, k=4) == 1 * 4 + 2 * 4
    assert dp_balance.ring_step_count(4, 2, k=4, n_layers=3) == 3 * 12
    assert dp_balance.ring_step_count(4, 1) == 0


def test_overlapped_ring_hops():
    """The double-buffered ring hides the cp-1 K/V prefetch rotations of
    every forward AND backward under their kernels; the remaining exposed
    hops are exactly the n_bwd dk/dv accumulator hops home."""
    assert dp_balance.overlapped_ring_hops(7, 4, 2) == 1 * (7 + 4)
    assert dp_balance.overlapped_ring_hops(7, 4, 2, n_layers=3) == 3 * 11
    assert dp_balance.overlapped_ring_hops(4, 4, 1) == 0
    for n_fwd, n_bwd, cp, nl in [(7, 4, 2, 1), (4, 4, 4, 3), (1, 1, 8, 2)]:
        total = dp_balance.ring_hops(n_fwd, n_bwd, cp, nl)
        hidden = dp_balance.overlapped_ring_hops(n_fwd, n_bwd, cp, nl)
        assert 0 < hidden < total
        assert total - hidden == nl * n_bwd


# --------------------------------------------------------------- planner ----
@pytest.mark.parametrize("world_size", [1, 2, 4, 8])
@pytest.mark.parametrize("policy", ["lpt", "round_robin"])
def test_every_unit_assigned_exactly_once(world_size, policy):
    units = sample_units(seed=1)
    plan = dp_balance.plan_assignment(units, world_size, policy=policy)
    assigned = [u for stream in plan.rank_units for u in stream]
    assert sorted(id(u) for u in assigned) == sorted(id(u) for u in units)


def test_lpt_greedy_balance_bound():
    """Greedy invariant: max rank load <= mean load + largest unit. This is
    the bound that keeps the max/min token-work ratio controlled whenever no
    single unit dominates the batch."""
    for seed in range(5):
        units = sample_units(seed=seed)
        for R in (2, 4, 8):
            plan = dp_balance.plan_assignment(units, R)
            total = sum(u.work for u in units)
            biggest = max(u.work for u in units)
            assert plan.max_work <= total / R + biggest + 1e-6
            if biggest <= total / R:     # no dominant unit -> ratio bounded
                assert plan.max_min_ratio <= 3.0


def test_lpt_beats_round_robin_on_long_tail():
    for seed in range(3):
        units = sample_units(seed=100 + seed, n=256, chunk_size=2048)
        for R in (4, 8):
            lpt = dp_balance.plan_assignment(units, R, policy="lpt")
            rr = dp_balance.plan_assignment(units, R, policy="round_robin")
            assert lpt.max_work <= rr.max_work + 1e-9


def test_determinism_under_input_permutation():
    units = sample_units(seed=3)
    plan_a = dp_balance.plan_assignment(units, 4)
    rng = random.Random(0)
    for _ in range(3):
        shuffled = list(units)
        rng.shuffle(shuffled)
        plan_b = dp_balance.plan_assignment(shuffled, 4)
        keys_a = [[(u.kind, u.key) for u in s] for s in plan_a.rank_units]
        keys_b = [[(u.kind, u.key) for u in s] for s in plan_b.rank_units]
        assert keys_a == keys_b


def test_dominant_group_isolated():
    """One group larger than everything else combined: LPT gives it a rank of
    its own and the imbalance equals its share (nothing can do better)."""
    big = dp_balance.WorkUnit("group", 0, 16, 1000.0)
    small = [dp_balance.WorkUnit("standalone", i, 1, 10.0) for i in range(6)]
    plan = dp_balance.plan_assignment([big] + small, 4)
    big_rank = [i for i, s in enumerate(plan.rank_units) if big in s]
    assert len(big_rank) == 1 and plan.rank_units[big_rank[0]] == [big]
    assert plan.max_work == pytest.approx(1000.0)


def test_empty_standalone_and_empty_units():
    lengths = {0: 100, 1: 90}          # only dependent groups, C=32
    groups, standalone = group_chunks(construct_chunks(lengths, 32))
    assert standalone == []
    units = dp_balance.units_from_chunks(groups, standalone)
    assert {u.kind for u in units} == {"group"}
    plan = dp_balance.plan_assignment(units, 4)
    waves, ws = dp_balance.wave_schedule(plan)
    assert ws.n_waves == 1 and len(waves[0]) == 4
    # fewer units than ranks: idle ranks pad the whole wave
    assert waves[0].count(None) == 2

    empty = dp_balance.plan_assignment([], 4)
    assert empty.imbalance == 1.0
    assert dp_balance.wave_schedule(empty)[1].n_waves == 0


def test_world_size_one_is_trivial():
    units = sample_units(seed=4)
    plan = dp_balance.plan_assignment(units, 1)
    assert len(plan.rank_units[0]) == len(units)
    assert plan.imbalance == pytest.approx(1.0)
    assert plan.max_min_ratio == pytest.approx(1.0)
    _, ws = dp_balance.wave_schedule(plan)
    assert ws.padded_slots == 0        # nothing to pad with one rank


def test_wave_padding_accounting():
    g5 = dp_balance.WorkUnit("group", 0, 5, 50.0)
    g2 = dp_balance.WorkUnit("group", 1, 2, 20.0)
    s1 = [dp_balance.WorkUnit("standalone", i, 1, 10.0) for i in range(2)]
    plan = dp_balance.DPPlan(2, [[g5], [g2] + s1], "manual")
    waves, ws = dp_balance.wave_schedule(plan)
    # wave0: (g5, g2) -> n=5, rank1 pads 3; wave1: (None, s) -> n=1, pad 1;
    # wave2: (None, s) -> n=1, pad 1
    assert ws.n_waves == 3
    assert ws.max_wave_chunks == [5, 1, 1]
    assert ws.padded_slots == 5
    assert ws.total_slots == (5 + 1 + 1) * 2


# ------------------------------------------------ materialized-unit costs ---
def test_units_from_materialized_matches_chunk_units():
    """The executor-side unit builder (from padded arrays) must agree with
    the benchmark-side builder (from Chunk metadata)."""
    from repro.core.chunking import materialize_chunk
    rng = np.random.RandomState(0)
    lengths = {0: 80, 1: 9, 2: 14, 3: 30}
    seqs = {i: rng.randint(1, 97, size=l).astype(np.int32)
            for i, l in lengths.items()}
    groups, standalone = group_chunks(construct_chunks(lengths, 32))
    u_chunks = dp_balance.units_from_chunks(groups, standalone, k=1)
    gb = [[materialize_chunk(c, seqs) for c in g] for g in groups.values()]
    sb = [materialize_chunk(c, seqs) for c in standalone]
    u_mat = dp_balance.units_from_materialized(gb, sb, k=1)
    works_a = sorted(round(u.work, 6) for u in u_chunks)
    works_b = sorted(round(u.work, 6) for u in u_mat)
    assert works_a == works_b


# ------------------------------------------- execution equivalence (slow) ---
DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.core import chunking, chunked_step
from repro.models import api
from repro.launch.mesh import make_data_mesh

def run_family(family, lengths, C, k, policy):
    base = dict(name=f"tiny-{family}", family=family, num_layers=2,
                d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                d_ff=128, vocab_size=97, dtype="float32",
                rope_theta=10_000.0)
    if family == "ssm":
        base.update(num_heads=0, num_kv_heads=0, d_ff=0, ssm_state=16,
                    ssm_head_dim=32, ssm_chunk=16)
    cfg = ModelConfig(**base)
    rng = np.random.RandomState(0)
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in lengths.items()}
    params = api.init_params(cfg, jax.random.PRNGKey(4))
    groups, standalone = chunking.group_chunks(
        chunking.construct_chunks(lengths, C))
    dev = lambda m: {kk: jnp.asarray(v) for kk, v in m.items()}
    gb = [[dev(chunking.materialize_chunk(c, seqs)) for c in g]
          for g in groups.values()]
    sb = [dev(chunking.materialize_chunk(c, seqs)) for c in standalone]
    l1, g1, _ = chunked_step.run_batch(cfg, params, gb, sb, k=k)
    mesh = make_data_mesh(4)
    l4, g4, _ = chunked_step.run_batch(cfg, params, gb, sb, k=k, mesh=mesh,
                                       plan_policy=policy)
    np.testing.assert_allclose(float(l4), float(l1), rtol=1e-5)
    for x, y in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-4, atol=5e-5)
    print(family, policy, "ok", float(l1))

# mixed: one 3-chunk group, one 2-chunk group, packed shorts + a dummy-padded
# wave (7 units on 4 ranks)
LEN = {0: 80, 1: 9, 2: 14, 3: 5, 4: 30, 5: 70, 6: 40, 7: 26, 8: 18}
run_family("dense", LEN, 32, 1, "lpt")
run_family("dense", LEN, 32, 2, "round_robin")
run_family("ssm",   LEN, 32, 1, "lpt")
# fewer units than ranks (idle ranks all-dummy)
run_family("dense", {0: 40, 1: 12}, 32, 1, "lpt")
print("DP-EQUIV-OK")
"""


@pytest.mark.slow
def test_dp_matches_single_device_on_4_devices():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", DP_SCRIPT], env=env,
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=540)
    assert "DP-EQUIV-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
