"""Gradient-equivalence suite for the trainable flash attention kernel.

The custom_vjp Pallas backward (`_flash_bwd_dq` / `_flash_bwd_dkv`, interpret
mode) must match the `jax.vjp(sdpa-ref)` oracle to <= 1e-5 across the full
mask contract: GQA, softcap, sliding window, prefix lengths {0, C, 3C},
packed segments, and capacity-padded prefixes (seg=0 slots interleaved
mid-K). This is what lets Algorithm 2 route *training* through the kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.chunked_attention import _flash_fwd, chunked_prefix_attention

TOL = dict(rtol=1e-5, atol=1e-5)


def rand_attn(key, B, T, P, Hq, Hkv, D, packed=False):
    ks = jax.random.split(key, 5)
    S = P + T
    q = jax.random.normal(ks[0], (B, Hq, T, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    if packed:
        assert P == 0
        split = T // 3
        q_seg = jnp.where(jnp.arange(T) < split, 1, 2)[None].repeat(B, 0)
        q_pos = jnp.where(jnp.arange(T) < split, jnp.arange(T),
                          jnp.arange(T) - split)[None].repeat(B, 0)
        k_seg, k_pos = q_seg, q_pos
    else:
        q_pos = (P + jnp.arange(T))[None].repeat(B, 0)
        q_seg = jnp.ones((B, T), jnp.int32)
        k_pos = jnp.arange(S)[None].repeat(B, 0)
        k_seg = jnp.ones((B, S), jnp.int32)
    return q, k, v, q_pos, k_pos, q_seg, k_seg


def kernel_vs_oracle_grads(args, *, window=0, softcap=0.0, block=32):
    """Returns ((dq,dk,dv) kernel, (dq,dk,dv) oracle) for a random-cotangent
    scalar loss sum(out * cot)."""
    q, k, v = args[:3]
    cot = jax.random.normal(jax.random.PRNGKey(99), q.shape)

    def loss_kernel(q, k, v):
        o = chunked_prefix_attention(q, k, v, *args[3:], window=window,
                                     softcap=softcap, block_q=block,
                                     block_k=block, interpret=True)
        return jnp.vdot(o, cot)

    def loss_oracle(q, k, v):
        o = ref.chunked_prefix_attention_ref(q, k, v, *args[3:],
                                             window=window, softcap=softcap)
        return jnp.vdot(o, cot)

    gk = jax.grad(loss_kernel, (0, 1, 2))(q, k, v)
    go = jax.grad(loss_oracle, (0, 1, 2))(q, k, v)
    return gk, go


def assert_grads_close(gk, go):
    for a, b, name in zip(gk, go, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   err_msg=name, **TOL)


@pytest.mark.parametrize("B,T,P,Hq,Hkv,D,window,softcap", [
    (1, 64, 0, 4, 2, 32, 0, 0.0),        # prefix 0 (standalone), GQA
    (1, 64, 64, 4, 4, 32, 0, 0.0),       # prefix C, MHA
    (2, 64, 192, 8, 2, 32, 0, 0.0),      # prefix 3C, deep GQA
    (1, 64, 64, 4, 2, 32, 48, 0.0),      # sliding window
    (1, 64, 64, 4, 2, 32, 0, 30.0),      # softcap
    (1, 64, 128, 4, 2, 32, 32, 20.0),    # window + softcap + prefix 2C
])
def test_custom_vjp_matches_oracle(B, T, P, Hq, Hkv, D, window, softcap):
    args = rand_attn(jax.random.PRNGKey(0), B, T, P, Hq, Hkv, D)
    assert_grads_close(*kernel_vs_oracle_grads(args, window=window,
                                               softcap=softcap))


def test_packed_segments_grads():
    args = rand_attn(jax.random.PRNGKey(1), 2, 96, 0, 4, 2, 32, packed=True)
    assert_grads_close(*kernel_vs_oracle_grads(args))


def test_padded_capacity_grads_and_masked_slots_zero():
    """Capacity-padded StateStore layout: K/V = [prefix capacity | own] where
    only the first `used` capacity slots are live (seg=0 tail). Grads must
    match the oracle AND be exactly zero on the masked capacity slots."""
    B, T, used, cap, Hq, Hkv, D = 1, 64, 64, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    S = cap + T
    q = jax.random.normal(ks[0], (B, Hq, T, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    slot = jnp.arange(S)
    live = (slot < used) | (slot >= cap)
    k_seg = jnp.where(live, 1, 0)[None].repeat(B, 0)
    k_pos = jnp.where(slot < cap, slot, used + slot - cap)[None].repeat(B, 0)
    q_pos = (used + jnp.arange(T))[None].repeat(B, 0)
    q_seg = jnp.ones((B, T), jnp.int32)
    args = (q, k, v, q_pos, k_pos, q_seg, k_seg)
    gk, go = kernel_vs_oracle_grads(args)
    assert_grads_close(gk, go)
    dead = np.asarray(~live)
    assert np.all(np.asarray(gk[1])[:, :, dead] == 0.0)
    assert np.all(np.asarray(gk[2])[:, :, dead] == 0.0)


def test_forward_lse_matches_ref():
    """The softmax-LSE residual the forward emits (incl. the fully-masked-row
    sentinel) is what the backward trusts — pin it against the ref."""
    args = list(rand_attn(jax.random.PRNGKey(3), 1, 64, 64, 4, 2, 32))
    args[5] = args[5].at[:, -16:].set(0)     # fully-masked query rows
    w = jnp.zeros((1,), jnp.int32)
    o, lse = _flash_fwd(*args[:3], *args[3:], w, softcap=0.0, block_q=32,
                        block_k=32, interpret=True)
    o_ref, lse_ref = ref.chunked_prefix_attention_ref(*args, return_lse=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), **TOL)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               rtol=1e-5, atol=1e-5)


def test_ops_wrapper_grads_with_padding():
    """Grad flows through the (B,T,H,D) wrapper's transposes and block
    padding; pad-slot cotangents must route to zero, not corrupt dk/dv."""
    B, T, P, Hq, Hkv, D = 2, 50, 40, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, P + T, Hkv, D))
    v = jax.random.normal(ks[2], (B, P + T, Hkv, D))
    q_pos = (P + jnp.arange(T))[None].repeat(B, 0)
    k_pos = jnp.arange(P + T)[None].repeat(B, 0)
    q_seg = jnp.ones((B, T), jnp.int32)
    k_seg = jnp.ones((B, P + T), jnp.int32)
    cot = jax.random.normal(ks[3], q.shape)

    def loss_kernel(q, k, v):
        o = ops.chunk_attention(q, k, v, q_pos, k_pos, q_seg, k_seg,
                                window=24, block_q=32, block_k=32)
        return jnp.vdot(o, cot)

    def loss_oracle(q, k, v):
        o = ref.chunked_prefix_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), q_pos, k_pos, q_seg, k_seg, window=24)
        return jnp.vdot(o.transpose(0, 2, 1, 3), cot)

    gk = jax.grad(loss_kernel, (0, 1, 2))(q, k, v)
    go = jax.grad(loss_oracle, (0, 1, 2))(q, k, v)
    assert_grads_close(gk, go)


def test_traced_window_grads_one_compile():
    """The window rides as a dynamic scalar: grads under jit must be correct
    for different window values WITHOUT retracing per value (the per-layer
    local/global alternation contract)."""
    args = rand_attn(jax.random.PRNGKey(5), 1, 64, 64, 4, 2, 32)
    q, k, v = args[:3]
    cot = jax.random.normal(jax.random.PRNGKey(6), q.shape)
    traces = []

    @jax.jit
    def grads(w):
        traces.append(1)
        def loss(q, k, v):
            o = chunked_prefix_attention(q, k, v, *args[3:], window=w,
                                         block_q=32, block_k=32,
                                         interpret=True)
            return jnp.vdot(o, cot)
        return jax.grad(loss, (0, 1, 2))(q, k, v)

    for w in (16, 48):
        gk = grads(jnp.int32(w))
        go = jax.grad(
            lambda q, k, v: jnp.vdot(ref.chunked_prefix_attention_ref(
                q, k, v, *args[3:], window=w), cot), (0, 1, 2))(q, k, v)
        assert_grads_close(gk, go)
    assert len(traces) == 1, "dynamic window must not fragment the jit cache"


# ------------------------------------------------- full-model training path --
@pytest.mark.slow
@pytest.mark.parametrize("variant", ["plain", "gemma2"])
def test_run_group_equivalence_pallas_backend(variant):
    """Algorithm 2 with attn_backend='pallas_interpret' (training routed
    through the custom_vjp kernel, capacity-padded StateStore) matches the
    full-sequence XLA step: loss and all parameter grads."""
    import dataclasses
    from test_chunked_equivalence import (assert_trees_close, chunked_run,
                                          full_reference, tiny)
    kw = dict(attn_backend="pallas_interpret")
    if variant == "gemma2":
        kw.update(sliding_window=40, local_global_alternate=True,
                  attn_softcap=50.0)
    cfg = tiny("dense", **kw)
    from repro.models import api
    rng = np.random.RandomState(7)
    seq = rng.randint(1, cfg.vocab_size, size=96).astype(np.int32)
    params = api.init_params(cfg, jax.random.PRNGKey(8))
    ref_loss, ref_grads = full_reference(
        dataclasses.replace(cfg, attn_backend="xla"), params, seq)
    loss, grads, _ = chunked_run(cfg, params, seq, 32, 2)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    assert_trees_close(grads, ref_grads)
