"""Context-parallel ("seq" axis) benchmark — ring cost and balance analytics.

All metrics are deterministic planner/geometry math (no devices needed, no
walltime), so every scalar under ``gate`` is CI-gated by check_regression:

  * ring steps: analytic ppermute counts (`dp_balance.ring_step_count` — the
    CP executors report exactly this in ``stats.ring_steps``) for a paper-CDF
    batch, per cp;
  * per-rank token-work balance: planner imbalance with and without a
    ``cp_threshold`` on a dp x cp mesh — the threshold keeps short units off
    the ring, which REDUCES imbalance because a ring-eligible long-tail group
    is costed at 1/cp and stops dominating its rank;
  * peak per-device K/V bytes vs cp: the StateStore capacity shard
    (cap/cp slots per rank, model geometry of granite-3-8b) plus the
    circulating ring shard — the 1/cp scaling that removes the one-device
    ChunkSize cap;
  * ring overlap: hops whose ppermute is issued concurrently with the
    previous hop's attention kernel (`dp_balance.overlapped_ring_hops`,
    exactly the executors' ``stats.overlapped_hops``) and the cost model's
    exposed comm units for the paper-CDF tail group
    (`planner.ring_comm_cost(..., overlap=True)`);
  * host-offloaded StateStore: per-device *resident* store bytes for the
    tail group with cold prefix buckets in pinned host memory
    (`planner.statestore_device_bytes`) vs keeping every version on device.
"""
from __future__ import annotations

import numpy as np

from repro.configs.registry import get_arch
from repro.core import dp_balance, planner
from repro.core.chunking import construct_chunks, group_chunks
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF

# batch=1024 at ChunkSize 2048 actually draws the paper CDF's tail (the
# seed-0 batch contains a 74-chunk / 150K-token group) — smaller batches at
# larger ChunkSize fold into equal bins and there is no ring story to tell
CHUNK_SIZE = 2048
GLOBAL_BATCH = 1024
SEED = 0
K = 2
CPS = (1, 2, 4, 8)
CP_THRESHOLD = 2 * CHUNK_SIZE        # units of >= 2 chunks ride the ring


def _batch_units(cp: int, cp_threshold: int):
    s = LongTailSampler(PAPER_EVAL_CDF, seed=SEED, max_len=262_144)
    lengths = dict(enumerate(s.sample_batch_lengths(GLOBAL_BATCH)))
    groups, standalone = group_chunks(construct_chunks(lengths, CHUNK_SIZE))
    return dp_balance.units_from_chunks(groups, standalone, k=K, cp=cp,
                                        cp_threshold=cp_threshold)


def kv_bytes_per_device(cfg, n_chunks: int, cp: int) -> int:
    """Peak per-device K/V for one group: the StateStore capacity shard
    (cap/cp slots) + one circulating ring shard ((cap + C)/cp slots of k+v
    for the layer currently in flight)."""
    hd = cfg.resolved_head_dim
    per_tok = 2 * cfg.padded_num_kv_heads * hd * 2          # k+v, bf16
    cap = dp_balance.prefix_capacity(n_chunks, CHUNK_SIZE)
    store = cfg.num_layers * cap // cp * per_tok
    ring = (cap + CHUNK_SIZE) // cp * per_tok
    return store + ring


def run():
    cfg = get_arch("granite-3-8b")
    gate = {}
    rows = []

    longest = max(u.n_chunks for u in _batch_units(1, 0))
    print(f"paper-CDF batch={GLOBAL_BATCH}, ChunkSize={CHUNK_SIZE}, K={K}, "
          f"longest group = {longest} chunks")
    print("cp,ring_steps,imbalance_all_ring,imbalance_thresholded,"
          "kv_bytes_per_device_longest")
    hd = cfg.resolved_head_dim
    per_tok = 2 * cfg.padded_num_kv_heads * hd * 2           # k+v, bf16
    for cp in CPS:
        units = _batch_units(cp, 0)
        ring_steps = sum(
            dp_balance.ring_step_count(u.n_chunks, cp, k=K,
                                       n_layers=cfg.num_layers)
            for u in units if u.ring)
        # hops the double-buffered ring issues concurrently with the
        # previous hop's kernel (the executors' stats.overlapped_hops)
        overlapped = sum(
            dp_balance.overlapped_ring_hops(
                u.n_chunks + max(u.n_chunks - K, 0), u.n_chunks, cp,
                n_layers=cfg.num_layers)
            for u in units if u.ring)
        # cost-model comm that stays EXPOSED for the tail group once the
        # overlap hides hop latency behind the kernel
        comm_serial = planner.ring_comm_cost(longest, CHUNK_SIZE, cp, k=K)
        comm_exposed = planner.ring_comm_cost(longest, CHUNK_SIZE, cp, k=K,
                                              overlap=True)
        # per-device store residency for the tail group: every prefix
        # version on device vs cold buckets offloaded to pinned host memory
        store_resident = planner.statestore_device_bytes(
            longest, CHUNK_SIZE, cp, n_layers=cfg.num_layers,
            bytes_per_token=per_tok, k=K)
        store_offload = planner.statestore_device_bytes(
            longest, CHUNK_SIZE, cp, n_layers=cfg.num_layers,
            bytes_per_token=per_tok, k=K, offload=True)
        # planner balance on a (dp=4) x cp mesh, all units on the ring vs
        # only long-tail units (cp_threshold)
        imb_all = dp_balance.plan_assignment(units, 4).imbalance
        units_thr = _batch_units(cp, CP_THRESHOLD)
        imb_thr = dp_balance.plan_assignment(units_thr, 4).imbalance
        kvb = kv_bytes_per_device(cfg, longest, cp)
        rows.append({"cp": cp, "ring_steps": ring_steps,
                     "overlapped_hops": overlapped,
                     "exposed_comm_cost": round(comm_exposed, 3),
                     "serial_comm_cost": round(comm_serial, 3),
                     "statestore_resident_bytes": int(store_resident),
                     "statestore_offload_bytes": int(store_offload),
                     "imbalance_all_ring": imb_all,
                     "imbalance_thresholded": imb_thr,
                     "kv_bytes_per_device_longest_group": kvb,
                     "ring_units": sum(u.ring for u in units),
                     "ring_units_thresholded": sum(u.ring for u in units_thr)})
        print(f"{cp},{ring_steps},{imb_all:.4f},{imb_thr:.4f},{kvb}")
        gate[f"ring_steps_cp{cp}"] = ring_steps
        gate[f"imbalance_thresholded_cp{cp}"] = round(imb_thr, 6)
        gate[f"kv_bytes_per_device_cp{cp}"] = kvb
        gate[f"overlapped_hops_serial_remainder_cp{cp}"] = \
            ring_steps - overlapped
        gate[f"exposed_comm_cost_cp{cp}"] = round(comm_exposed, 3)
        gate[f"statestore_offload_bytes_cp{cp}"] = int(store_offload)

    # the point of the axis: per-device K/V scales ~1/cp
    assert rows[-1]["kv_bytes_per_device_longest_group"] * (CPS[-1] // 2) \
        < rows[0]["kv_bytes_per_device_longest_group"]
    # overlap hides most hops and never inflates cost; offload drops
    # residency by ~(n+1)/(k+2) on the 74-chunk tail group
    for r in rows:
        if r["cp"] > 1:
            assert 0 < r["overlapped_hops"] < r["ring_steps"]
            assert r["exposed_comm_cost"] <= r["serial_comm_cost"]
            assert r["statestore_offload_bytes"] * 4 \
                < r["statestore_resident_bytes"]
    return {
        "config": {"arch": cfg.name, "chunk_size": CHUNK_SIZE,
                   "global_batch": GLOBAL_BATCH, "k": K, "seed": SEED,
                   "cp_threshold": CP_THRESHOLD, "dp": 4},
        "rows": rows,
        "gate": gate,
        "note": "all metrics are deterministic planner/geometry math "
                "(gated in CI); ring_steps / overlapped_hops match the "
                "executors' stats accounting; the gate carries the "
                "serial REMAINDER (ring_steps - overlapped_hops) so that "
                "'higher is worse' holds; statestore bytes are the "
                "planner.statestore_device_bytes model for the 74-chunk "
                "tail group (resident = every prefix version on device, "
                "offload = latest + K captured + 1 in-flight + prefetch "
                "window)",
    }


if __name__ == "__main__":
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args()
    payload = run()
    os.makedirs(args.json_dir, exist_ok=True)
    path = os.path.join(args.json_dir, "BENCH_cp.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path}")
