"""Attention-backend microbenchmarks: fwd+bwd walltime, compile counts, and
the dense-vs-flash crossover.

    PYTHONPATH=src python -m benchmarks.attention [--json-dir DIR]

Three sections:
  * fwd / fwd+bwd walltime of the sdpa (dense-mask) vs blockwise
    (online-softmax) XLA paths across kv lengths, reporting the first kv
    length where blockwise wins (the dense-vs-flash crossover a deployment
    should feed into `blockwise_threshold`);
  * the Pallas flash kernel fwd and fwd+bwd in interpret mode — a
    correctness/latency *proxy* only (Python-interpreted blocks; on TPU the
    same pallas_call compiles);
  * chunk-fn compile counts for a mixed batch of group sizes with the
    static-shape StateStore: O(#capacity buckets), pinned against the
    O(max-group-len) the grow-by-C prefix would pay.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, n=5):
    fn()                                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _attn_inputs(S, B=1, Hq=4, Hkv=2, D=64):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.arange(S)[None].repeat(B, 0)
    seg = jnp.ones((B, S), jnp.int32)
    return q, k, v, pos, seg


def _xla_rows(kv_lens=(512, 1024, 2048, 4096)):
    """sdpa vs blockwise fwd and fwd+bwd walltime; crossover kv length."""
    from repro.models import layers as L

    rows = []
    crossover = {"fwd": None, "bwd": None}
    for S in kv_lens:
        q, k, v, pos, seg = _attn_inputs(S)

        def sdpa_fn(q, k, v):
            mask = L.make_attention_mask(pos, pos, seg, seg, causal=True)
            return L.sdpa(q, k, v, mask)

        def blockwise_fn(q, k, v):
            blk = min(512, S)
            def mask_fn(qi, ki):
                qp = jax.lax.dynamic_slice_in_dim(pos, qi, blk, 1)
                qs = jax.lax.dynamic_slice_in_dim(seg, qi, blk, 1)
                kp = jax.lax.dynamic_slice_in_dim(pos, ki, blk, 1)
                ks_ = jax.lax.dynamic_slice_in_dim(seg, ki, blk, 1)
                return L.make_attention_mask(qp, kp, qs, ks_, causal=True)
            return L.blockwise_sdpa(q, k, v, mask_fn, q_block=blk,
                                    kv_block=blk)

        row = {"kv_len": S}
        for name, fn in (("sdpa", sdpa_fn), ("blockwise", blockwise_fn)):
            fwd = jax.jit(lambda q, k, v, f=fn: f(q, k, v).sum())
            bwd = jax.jit(jax.grad(lambda q, k, v, f=fn: f(q, k, v).sum(),
                                   (0, 1, 2)))
            row[f"{name}_fwd_us"] = _timeit(
                lambda: jax.block_until_ready(fwd(q, k, v)), n=3)
            row[f"{name}_fwdbwd_us"] = _timeit(
                lambda: jax.block_until_ready(bwd(q, k, v)), n=3)
        if crossover["fwd"] is None and \
                row["blockwise_fwd_us"] < row["sdpa_fwd_us"]:
            crossover["fwd"] = S
        if crossover["bwd"] is None and \
                row["blockwise_fwdbwd_us"] < row["sdpa_fwdbwd_us"]:
            crossover["bwd"] = S
        rows.append(row)
    return rows, crossover


def _pallas_rows():
    """Interpret-mode flash kernel fwd and fwd+bwd (correctness proxy)."""
    from repro.kernels import ops

    B, T, P, Hq, Hkv, D = 1, 128, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, P + T, Hkv, D))
    v = jax.random.normal(ks[2], (B, P + T, Hkv, D))
    qp = (P + jnp.arange(T))[None]
    kp = jnp.arange(P + T)[None]
    ones_q = jnp.ones((B, T), jnp.int32)
    ones_k = jnp.ones((B, P + T), jnp.int32)

    def fwd(q, k, v):
        return ops.chunk_attention(q, k, v, qp, kp, ones_q, ones_k,
                                   block_q=64, block_k=64).sum()

    bwd = jax.grad(fwd, (0, 1, 2))
    return {
        "shape": {"T": T, "P": P, "Hq": Hq, "Hkv": Hkv, "D": D},
        "fwd_us": _timeit(lambda: jax.block_until_ready(fwd(q, k, v)), n=3),
        "fwdbwd_us": _timeit(lambda: jax.block_until_ready(bwd(q, k, v)),
                             n=3),
        "note": "interpret mode (Python-executed blocks) — correctness "
                "proxy, not TPU walltime",
    }


def _compile_count_rows(C=16):
    """Chunk-fn compiles for a mixed batch of group sizes {1,2,4,5}."""
    from repro.core import chunked_step, chunking
    from repro.models import api
    from repro.configs.base import ModelConfig

    cfg = ModelConfig(name="bench-attn-compiles", family="dense",
                      num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab_size=97, dtype="float32",
                      rope_theta=10_000.0)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    lengths = {0: C, 1: 2 * C, 2: 4 * C, 3: 5 * C}
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in lengths.items()}
    chunks = chunking.construct_chunks(lengths, C)
    groups, standalone = chunking.group_chunks(chunks)
    gb = [[{k: jnp.asarray(v) for k, v in
            chunking.materialize_chunk(c, seqs).items()} for c in g]
          for g in groups.values()]
    sb = [{k: jnp.asarray(v) for k, v in
           chunking.materialize_chunk(c, seqs).items()} for c in standalone]

    chunked_step.reset_trace_log()
    t0 = time.perf_counter()
    chunked_step.run_batch(cfg, params, gb, sb, k=1)
    wall = time.perf_counter() - t0
    compiles = len(chunked_step.TRACE_EVENTS)
    buckets = sorted({p for _, p, _ in chunked_step.TRACE_EVENTS})
    total_steps = sum(len(g) for g in gb) + len(sb)
    # grow-by-C would compile one executable per distinct prefix length,
    # i.e. once per chunk index up to the longest group
    legacy = max([len(g) for g in gb] + [1])
    chunked_step.reset_trace_log()
    return {
        "chunk_size": C,
        "group_sizes": [len(g) for g in gb] + [1] * len(sb),
        "chunk_fn_compiles": compiles,
        "capacity_buckets": [int(b) for b in buckets],
        "legacy_compiles_grow_by_C": legacy,
        "total_chunk_steps": total_steps,
        "batch_walltime_s": wall,
        "note": "compiles == #capacity buckets (static-shape StateStore); "
                "legacy = distinct prefix lengths the grow-by-C store "
                "would have compiled",
    }


def run() -> dict:
    xla_rows, crossover = _xla_rows()
    print("kv_len,sdpa_fwd_us,blockwise_fwd_us,sdpa_fwdbwd_us,"
          "blockwise_fwdbwd_us")
    for r in xla_rows:
        print(f"{r['kv_len']},{r['sdpa_fwd_us']:.0f},"
              f"{r['blockwise_fwd_us']:.0f},{r['sdpa_fwdbwd_us']:.0f},"
              f"{r['blockwise_fwdbwd_us']:.0f}")
    print(f"dense-vs-flash crossover: fwd @ kv_len={crossover['fwd']}, "
          f"fwd+bwd @ kv_len={crossover['bwd']}")

    pallas = _pallas_rows()
    print(f"pallas interpret fwd {pallas['fwd_us']:.0f}us, "
          f"fwd+bwd {pallas['fwdbwd_us']:.0f}us ({pallas['note']})")

    compiles = _compile_count_rows()
    print(f"chunk-fn compiles for group sizes {compiles['group_sizes']}: "
          f"{compiles['chunk_fn_compiles']} "
          f"(buckets {compiles['capacity_buckets']}; grow-by-C would be "
          f"{compiles['legacy_compiles_grow_by_C']})")

    return {"xla": xla_rows, "crossover": crossover, "pallas": pallas,
            "compile_counts": compiles}


if __name__ == "__main__":
    import argparse
    from benchmarks.run import emit_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args()
    emit_json("attention", run(), args.json_dir)
