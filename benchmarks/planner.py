"""Heterogeneous planner benchmark — solved per-wave cp vs best fixed config.

The PR-6 acceptance metric: on paper-CDF long-tail batches at world size 8,
the heterogeneous plan (`planner.solve_world` — per-wave cp, mesh
factorization searched) must beat the best FIXED (cp, ChunkSize, K) config
that `tuning.grid_search` world mode can find, by >= 10% in schedule_sim
makespan units. Everything here is deterministic host math (`planner
.wave_cost` / `schedule_sim.simulate_rotation` — no devices, no walltime in
the gate), so the win is CI-gated by check_regression:

  * ``gate.fixed_makespan``   — best fixed config's mean makespan;
  * ``gate.hetero_makespan``  — solved heterogeneous plan's mean makespan;
  * ``gate.hetero_to_fixed_ratio`` — the acceptance ratio (<= 0.90, also
    asserted in-benchmark so the bench itself fails on a planner regression).

Solver walltime is emitted report-only (``_s`` suffix).
"""
from __future__ import annotations

import time

from repro.core import tuning
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF

WORLD = 8
PP = 1
SEED = 0
N_BATCHES = 4
# 1024 sequences actually draws the paper CDF's tail (the seed-0 batch has a
# 74-chunk / 150K-token group at C=2048) — small batches are all singleton
# chunks and there is no heterogeneity story to solve
GLOBAL_BATCH = 1024
MAX_LEN = 262_144
BUDGET = 32_768                    # K * ChunkSize live-activation budget
CHUNK_SIZES = (2048, 4096, 8192)
KS = (1, 2)
ACCEPT_RATIO = 0.90                # solved must be >= 10% faster than fixed


def paper_batches(n_batches: int = N_BATCHES, batch: int = GLOBAL_BATCH,
                  seed: int = SEED):
    s = LongTailSampler(PAPER_EVAL_CDF, seed=seed, max_len=MAX_LEN)
    return [dict(enumerate(s.sample_batch_lengths(batch)))
            for _ in range(n_batches)]


def run():
    batches = paper_batches()
    t0 = time.perf_counter()
    r = tuning.grid_search(batches, pp=PP, memory_token_budget=BUDGET,
                           chunk_sizes=CHUNK_SIZES, ks=KS,
                           world_size=WORLD, include_heterogeneous=True)
    solve_s = time.perf_counter() - t0

    fixed = [c for c in r.ranked if not c.heterogeneous]
    het = [c for c in r.ranked if c.heterogeneous]
    best_fixed, best_het = fixed[0], het[0]
    ratio = best_het.makespan / best_fixed.makespan

    print(f"world={WORLD} pp={PP} batches={N_BATCHES}x{GLOBAL_BATCH} "
          f"budget={BUDGET} candidates={len(r.ranked)} "
          f"(solve {solve_s:.2f}s)")
    print("rank,kind,dp,pp,cp,C,K,makespan")
    for i, c in enumerate(r.ranked[:10]):
        kind = "solve" if c.heterogeneous else "fixed"
        print(f"{i},{kind},{c.dp},{c.pp},{c.cp},{c.chunk_size},{c.k},"
              f"{c.makespan:.0f}")
    print(f"best fixed: {best_fixed.describe()}")
    print(f"best solve: {best_het.describe()}")
    print(f"hetero/fixed makespan ratio: {ratio:.3f} "
          f"(acceptance: <= {ACCEPT_RATIO})")

    # the PR's acceptance bar — a planner regression fails the bench itself,
    # not just the CI gate
    assert ratio <= ACCEPT_RATIO, (
        f"solved heterogeneous plan must beat the best fixed config by "
        f">= {1 - ACCEPT_RATIO:.0%}: ratio={ratio:.3f} "
        f"(fixed={best_fixed.makespan:.0f}, het={best_het.makespan:.0f})")

    rows = [{"kind": "solve" if c.heterogeneous else "fixed", "dp": c.dp,
             "pp": c.pp, "cp": c.cp, "chunk_size": c.chunk_size, "k": c.k,
             "makespan": round(c.makespan, 1),
             "memory_tokens": c.memory_tokens}
            for c in r.ranked]
    return {
        "config": {"world": WORLD, "pp": PP, "seed": SEED,
                   "n_batches": N_BATCHES, "global_batch": GLOBAL_BATCH,
                   "max_len": MAX_LEN, "memory_token_budget": BUDGET,
                   "chunk_sizes": list(CHUNK_SIZES), "ks": list(KS)},
        "rows": rows,
        "best_fixed": rows[r.ranked.index(best_fixed)],
        "best_hetero": rows[r.ranked.index(best_het)],
        "solve_walltime_s": round(solve_s, 3),
        "gate": {
            "fixed_makespan": round(best_fixed.makespan, 1),
            "hetero_makespan": round(best_het.makespan, 1),
            "hetero_to_fixed_ratio": round(ratio, 4),
        },
        "note": "deterministic planner math (schedule_sim units); the "
                "hetero_to_fixed_ratio <= 0.90 acceptance bar is asserted "
                "in-benchmark and gated in CI",
    }


if __name__ == "__main__":
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args()
    payload = run()
    os.makedirs(args.json_dir, exist_ok=True)
    path = os.path.join(args.json_dir, "BENCH_planner.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path}")
