"""Executor-measured pipeline benchmark: bubble ratio + state bytes vs K.

    PYTHONPATH=src python -m benchmarks.pipeline [--json-dir DIR]

Runs the real 2D (data x pipe) K-retention rotation executor
(distributed/pipeline.run_batch_pipelined) on a small dense model over a
long-tail chunk stream, sweeping K, and reports per K:

  * bubble ratio from the executor's own tick accounting (deterministic
    integer math — the CI regression gate reads it);
  * recompute counts and resident chunk-states;
  * StateStore K/V bytes (deterministic) and the analytic peak-state-bytes
    gate metric (StateStore + resident chunk-states in bytes);
  * measured vjp residual bytes and walltime (report-only: they move with
    the jax version / XLA partitioner, so they ride as informational);
  * the simulate_rotation prediction for the same stream, with an
    ``agrees`` flag (pinned true — apples-to-apples by construction).

Needs multiple devices: when run as a script it re-execs itself with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (run from the repo root;
benchmarks.run invokes it as a subprocess for the same reason).
"""
import argparse
import json
import os
import sys
import time

DEVICE_COUNT = 4


def _bench(json_dir: str) -> dict:
    import jax
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.core import chunked_step, chunking
    from repro.core.schedule_sim import simulate_rotation
    from repro.distributed import pipeline
    from repro.launch import mesh as mesh_lib
    from repro.models import api

    cfg = ModelConfig(name="bench-pipe", family="dense", num_layers=4,
                      d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=97, dtype="float32",
                      rope_theta=10_000.0)
    C = 32
    data, pipe = 2, 2
    mesh = mesh_lib.make_train_mesh(data, pipe)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    # long-tail stream: one 8-chunk group (the paper's tail sequence), a
    # 3-chunk group, and short sequences packing into standalone chunks
    rng = np.random.RandomState(0)
    lengths = {0: 8 * C - 5, 1: 3 * C, 2: 20, 3: 9, 4: 28, 5: 14, 6: 25}
    seqs = {i: rng.randint(1, cfg.vocab_size, size=l).astype(np.int32)
            for i, l in lengths.items()}
    chunks = chunking.construct_chunks(lengths, C)
    groups, standalone = chunking.group_chunks(chunks)
    gb = [[chunking.materialize_chunk(c, seqs) for c in g]
          for g in groups.values()]
    sb = [chunking.materialize_chunk(c, seqs) for c in standalone]

    kv_bytes_per_slot = (2 * cfg.num_layers * data * C
                         * cfg.padded_num_kv_heads * cfg.resolved_head_dim
                         * 4)                                  # k+v, fp32

    sweep = []
    for k in (1, 2, 4, 8):
        pipeline.reset_pipe_trace_log()
        t0 = time.perf_counter()
        loss, grads, st = chunked_step.run_batch(cfg, params, gb, sb, k=k,
                                                 mesh=mesh)
        jax.block_until_ready(grads)
        wall = time.perf_counter() - t0
        sim = simulate_rotation(st.wave_sizes, pipe, k)
        peak_state = (st.kv_store_bytes
                      + st.max_live_residuals * kv_bytes_per_slot)
        sweep.append({
            "k": k,
            "bubble_ratio": st.bubble_ratio,
            "sim_bubble_ratio": sim.bubble_ratio,
            "agrees": (abs(st.bubble_ratio - sim.bubble_ratio) < 1e-12
                       and st.recompute_calls == sim.recompute_count
                       and st.max_live_residuals
                       == sim.peak_resident_chunks),
            "recompute_chunks": st.recompute_calls,
            "resident_chunk_states": st.max_live_residuals,
            "kv_store_bytes": st.kv_store_bytes,
            "peak_state_bytes": peak_state,
            "residual_bytes_measured": st.peak_residual_bytes,
            "compile_count": len(pipeline.PIPE_TRACE_EVENTS),
            "wave_sizes": st.wave_sizes,
            "loss": float(loss),
            "walltime_s": wall,
        })

    gate = {}
    for row in sweep:
        gate[f"bubble_ratio_k{row['k']}"] = row["bubble_ratio"]
        gate[f"peak_state_bytes_k{row['k']}"] = row["peak_state_bytes"]
        gate[f"recompute_chunks_k{row['k']}"] = row["recompute_chunks"]
    gate["compile_count_total"] = sum(r["compile_count"] for r in sweep)

    payload = {
        "mesh": {"data": data, "pipe": pipe},
        "chunk_size": C,
        "stream_lengths": {str(kk): v for kk, v in lengths.items()},
        "kv_bytes_per_chunk_slot": kv_bytes_per_slot,
        "sweep": sweep,
        "gate": gate,
        "note": "bubble/recompute/state metrics are deterministic integer "
                "math (gated in CI); residual bytes and walltime depend on "
                "the jax version and ride report-only",
    }

    print("k,bubble_ratio,sim_bubble,recompute,resident,peak_state_bytes,"
          "residual_bytes,compiles,walltime_s")
    for r in sweep:
        print(f"{r['k']},{r['bubble_ratio']:.4f},"
              f"{r['sim_bubble_ratio']:.4f},{r['recompute_chunks']},"
              f"{r['resident_chunk_states']},{r['peak_state_bytes']},"
              f"{r['residual_bytes_measured']},{r['compile_count']},"
              f"{r['walltime_s']:.2f}")
    assert all(r["agrees"] for r in sweep), \
        "executor/simulator schedule accounting diverged"
    return payload


def emit(payload: dict, json_dir: str):
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, "BENCH_pipeline.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    args = ap.parse_args(argv)
    emit(_bench(args.json_dir), args.json_dir)


if __name__ == "__main__":
    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={DEVICE_COUNT}"
        ).strip()
        os.execv(sys.executable,
                 [sys.executable, "-m", "benchmarks.pipeline"] + sys.argv[1:])
    main()
