"""§Roofline — three-term roofline per (arch x shape x mesh) from the
dry-run's compiled artifacts (launch/dryrun.py --out dryrun_results.jsonl).

    compute    = HLO_FLOPs_per_device / peak_FLOPs     (197 TFLOP/s bf16 v5e)
    memory     = HLO_bytes_per_device / HBM_bw         (819 GB/s)
    collective = collective_bytes_per_device / link_bw (~50 GB/s ICI)

HLO numbers come from launch/hlo_analysis.py (loop-trip-count-aware — XLA's
own cost_analysis counts scan bodies once). MODEL_FLOPS = 6*N_active*tokens
for training, 2*N_active*tokens for prefill/decode; the ratio over HLO FLOPs
measures recompute/redundancy waste (remat target ~1/3 for full recompute).
"""
import json
import sys

from repro.configs.registry import ARCHS, get_arch, get_shape
from repro.models import api
import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def param_counts(cfg):
    """(total_params, active_params_per_token)."""
    shapes = jax.eval_shape(lambda k: api.init_params(cfg, k, max_seq=4096),
                            jax.random.PRNGKey(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if not cfg.num_experts:
        return total, total
    # active: experts contribute top-k/E of their weights
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    active = 0
    for path, x in flat:
        n = int(np.prod(x.shape))
        names = str([getattr(p, "key", "") for p in path])
        if ("'moe'" in names or "'moe_m'" in names or "'moe_a'" in names) \
                and any(s in names for s in ("w_gate", "w_up", "w_down")):
            n = n * cfg.experts_per_token // cfg.num_experts
        active += n
    return total, active


def model_flops_per_device(arch, shape_name, n_chips):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    total, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        f = 2.0 * active * tokens
    else:  # decode: one token per request
        tokens = shape.global_batch
        f = 2.0 * active * tokens
    return f / n_chips, total, active


def analyze_row(row):
    chips = 512 if row["mesh"] == "2x16x16" else 256
    t_c = row["flops"] / PEAK_FLOPS
    t_m = row["hbm_bytes"] / HBM_BW
    t_x = row["collective_total"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf, total, active = model_flops_per_device(row["arch"], row["shape"],
                                               chips)
    useful = mf / row["flops"] if row["flops"] else 0.0
    hints = {
        "compute": "cut recompute (remat policy) / skip non-causal blocks",
        "memory": "fuse or shrink activation traffic; bigger microbatch",
        "collective": "reshard to cut all-gathers; overlap collectives",
    }
    return {
        "arch": row["arch"], "shape": row["shape"], "mesh": row["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom, "model_flops_per_dev": mf,
        "useful_ratio": useful, "params_total": total,
        "params_active": active, "hint": hints[dom],
    }


def run(path="dryrun_results.jsonl", mesh="16x16"):
    try:
        with open(path) as fh:
            rows = [json.loads(line) for line in fh]
    except FileNotFoundError:
        print(f"roofline: {path} not found — run launch/dryrun.py --all first")
        return []
    out = []
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio")
    for row in rows:
        if row.get("skipped") or row.get("error"):
            continue
        if mesh and row["mesh"] != mesh:
            continue
        a = analyze_row(row)
        out.append(a)
        print(f"{a['arch']},{a['shape']},{a['mesh']},{a['compute_s']:.4f},"
              f"{a['memory_s']:.4f},{a['collective_s']:.4f},{a['dominant']},"
              f"{a['useful_ratio']:.3f}")
    return out


if __name__ == "__main__":
    run(*(sys.argv[1:] or []))
