"""CI perf-regression gate over the BENCH_*.json payloads.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --current bench --baseline benchmarks/baselines \
        [--threshold 0.15] [--require-all]

Compares the freshly produced JSONs against the committed baselines and
FAILS (exit 1) when a *gated* metric regresses by more than the threshold.
Gated metrics are deterministic schedule/compile/state measurements —
higher is worse for all of them:

  * BENCH_pipeline.json: every scalar under ``gate`` (bubble ratio,
    peak-state bytes and recompute count per K, total compile count);
  * BENCH_attention.json: ``compile_counts.chunk_fn_compiles`` (the
    static-shape StateStore's O(#buckets) compile guarantee).

Everything else — walltimes, latencies, throughput, measured residual
bytes — moves with the runner and the jax version, so it is printed
report-only (still visible in the job log and in the artifact bundle).

``--update`` rewrites the baselines from the current payloads (run locally
when a change legitimately shifts a gated metric, and commit the diff).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

# file -> list of dotted paths to gate; a trailing ".*" gates every scalar
# child of the addressed dict
GATED = {
    "BENCH_pipeline.json": ["gate.*"],
    "BENCH_attention.json": ["compile_counts.chunk_fn_compiles"],
    "BENCH_serving.json": [],          # latency/throughput: report-only
    "BENCH_cp.json": ["gate.*"],       # ring steps / balance / K/V bytes:
                                       # deterministic planner+geometry math
    "BENCH_planner.json": ["gate.*"],  # solved-vs-fixed makespans + ratio:
                                       # deterministic schedule_sim math
}

REPORT_ONLY_SUFFIXES = ("_us", "_s")
REPORT_ONLY_HINTS = ("walltime", "ttft", "e2e", "latency", "throughput",
                     "residual_bytes", "p50", "p99")


def _resolve(payload, dotted: str):
    """-> {full_path: scalar} for a dotted path (supports trailing '.*')."""
    parts = dotted.split(".")
    node = payload
    for i, p in enumerate(parts):
        if p == "*":
            assert i == len(parts) - 1, dotted
            prefix = ".".join(parts[:-1])
            return {f"{prefix}.{k}": v for k, v in sorted(node.items())
                    if isinstance(v, (int, float)) and not isinstance(v, bool)}
        if not isinstance(node, dict) or p not in node:
            return {}
        node = node[p]
    if isinstance(node, (int, float)) and not isinstance(node, bool):
        return {dotted: node}
    return {}


def check_file(name: str, current_dir: str, baseline_dir: str,
               threshold: float, require_all: bool):
    """-> (failures, rows). rows: (metric, base, cur, status)."""
    cur_path = os.path.join(current_dir, name)
    base_path = os.path.join(baseline_dir, name)
    if not os.path.exists(cur_path):
        # CI (--require-all) treats a bench that didn't run/emit as a
        # failure; locally you can gate a single fresh json against its
        # baseline without producing the others
        if require_all:
            return [f"{name}: missing from --current {current_dir} "
                    "(benchmark did not run or did not emit)"], []
        print(f"  [skip] {name}: not in --current {current_dir}")
        return [], []
    if not os.path.exists(base_path):
        return [f"{name}: no committed baseline at {base_path} "
                "(run with --update and commit it)"], []
    with open(cur_path) as f:
        cur = json.load(f)
    with open(base_path) as f:
        base = json.load(f)

    failures, rows = [], []
    for dotted in GATED[name]:
        base_m = _resolve(base, dotted)
        cur_m = _resolve(cur, dotted)
        for metric, bval in base_m.items():
            if metric not in cur_m:
                failures.append(f"{name}:{metric}: gated metric vanished")
                continue
            cval = cur_m[metric]
            # higher is worse for every gated metric; tiny baselines use an
            # absolute floor so 0 -> 0.1 noise can't divide by zero
            limit = bval * (1.0 + threshold) + (1e-9 if bval else threshold)
            status = "OK" if cval <= limit else "REGRESSED"
            rows.append((f"{name}:{metric}", bval, cval, status))
            if status != "OK":
                failures.append(
                    f"{name}:{metric}: {bval} -> {cval} "
                    f"(> {threshold:.0%} regression)")
    return failures, rows


def orphan_benchmarks(current_dir: str) -> list:
    """BENCH_*.json files in --current that the GATED registry doesn't know:
    a benchmark someone added (or renamed) without wiring it into the gate
    and committing a baseline. Under --require-all these FAIL the build —
    otherwise the new benchmark would upload artifacts forever while its
    regressions go unwatched."""
    if not os.path.isdir(current_dir):
        return []
    return sorted(f for f in os.listdir(current_dir)
                  if f.startswith("BENCH_") and f.endswith(".json")
                  and f not in GATED)


def report_only(name: str, current_dir: str, baseline_dir: str):
    """Print walltime-ish scalars side by side, informational."""
    cur_path = os.path.join(current_dir, name)
    base_path = os.path.join(baseline_dir, name)
    if not (os.path.exists(cur_path) and os.path.exists(base_path)):
        return

    def scalars(payload, prefix=""):
        out = {}
        if isinstance(payload, dict):
            for k, v in payload.items():
                out.update(scalars(v, f"{prefix}{k}."))
        elif isinstance(payload, list):
            for i, v in enumerate(payload):
                out.update(scalars(v, f"{prefix}{i}."))
        elif isinstance(payload, (int, float)) and not isinstance(
                payload, bool):
            key = prefix[:-1]
            leaf = key.rsplit(".", 1)[-1].lower()
            if (leaf.endswith(REPORT_ONLY_SUFFIXES)
                    or any(h in key.lower() for h in REPORT_ONLY_HINTS)):
                out[key] = payload
        return out

    with open(cur_path) as f:
        cur = scalars(json.load(f))
    with open(base_path) as f:
        base = scalars(json.load(f))
    for k in sorted(set(cur) & set(base)):
        b, c = base[k], cur[k]
        delta = (c - b) / b if b else 0.0
        print(f"  [report-only] {name}:{k}: {b:.6g} -> {c:.6g} "
              f"({delta:+.0%})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="bench")
    ap.add_argument("--baseline", default="benchmarks/baselines")
    ap.add_argument("--threshold", type=float, default=0.15)
    ap.add_argument("--require-all", action="store_true",
                    help="fail when any gated json is absent from --current "
                         "(CI mode; default skips absent files)")
    ap.add_argument("--update", action="store_true",
                    help="copy current payloads over the baselines")
    args = ap.parse_args(argv)

    if args.update:
        os.makedirs(args.baseline, exist_ok=True)
        for name in list(GATED) + orphan_benchmarks(args.current):
            src = os.path.join(args.current, name)
            if os.path.exists(src):
                shutil.copy(src, os.path.join(args.baseline, name))
                print(f"baseline updated: {name}")
        return 0

    all_failures = []
    for name in GATED:
        failures, rows = check_file(name, args.current, args.baseline,
                                    args.threshold, args.require_all)
        for metric, bval, cval, status in rows:
            print(f"  [gate] {metric}: {bval:.6g} -> {cval:.6g} [{status}]")
        report_only(name, args.current, args.baseline)
        all_failures += failures
    for name in orphan_benchmarks(args.current):
        if args.require_all:
            all_failures.append(
                f"{name}: produced in --current {args.current} but not in "
                "the GATED registry / no committed baseline — register it "
                "in benchmarks/check_regression.py, then run with --update "
                "and commit it")
        else:
            print(f"  [orphan] {name}: not in GATED registry (would fail "
                  "under --require-all)")
    if all_failures:
        print("\nPERF REGRESSION GATE FAILED:", file=sys.stderr)
        for msg in all_failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print(f"\nperf gate OK ({args.threshold:.0%} threshold)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
