"""Paper Tables 1-2 — sequence-length distribution of the synthetic samplers
vs the paper's reported CDFs.

Lengths come from `core.chunking.sample_lengths` — the same public helper the
serving arrival simulator (serving/frontend.py) draws from, so the benchmark
checks exactly the distribution the engine is exercised with.
"""
import numpy as np

from repro.core.chunking import sample_lengths
from repro.data.synthetic import LMSYS_CDF, PAPER_EVAL_CDF


def run(n=50_000):
    print("dataset,bucket,sampled_cdf,paper_cdf")
    for name, dist, cdf in [("paper_eval(T2)", "paper_eval", PAPER_EVAL_CDF),
                            ("lmsys(T1)", "lmsys", LMSYS_CDF)]:
        lens = np.asarray(sample_lengths(dist, n, seed=0))
        for ub, target in cdf[:-1]:
            print(f"{name},<{ub},{(lens < ub).mean():.5f},{target}")
        print(f"{name},max,{int(lens.max())},{cdf[-1][0]}")


if __name__ == "__main__":
    run()
