"""Paper Tables 1-2 — sequence-length distribution of the synthetic samplers
vs the paper's reported CDFs."""
from repro.data.synthetic import (LongTailSampler, LMSYS_CDF, PAPER_EVAL_CDF)


def run(n=50_000):
    print("dataset,bucket,sampled_cdf,paper_cdf")
    for name, cdf in [("paper_eval(T2)", PAPER_EVAL_CDF),
                      ("lmsys(T1)", LMSYS_CDF)]:
        s = LongTailSampler(cdf, seed=0)
        stats = s.bucket_stats(n)
        for ub, target in cdf[:-1]:
            print(f"{name},<{ub},{stats[ub]:.5f},{target}")
        print(f"{name},max,{stats['max']},{cdf[-1][0]}")


if __name__ == "__main__":
    run()
