"""Benchmark harness — one section per paper table/figure + microbenchmarks.

    PYTHONPATH=src python -m benchmarks.run [--json-dir DIR] [--list]
    PYTHONPATH=src python -m benchmarks.run --only dp_balance attention

Sections are declared in the SECTIONS registry below. Entries that emit a
payload dict additionally write ``BENCH_<name>.json`` (the machine-readable
flow CI's perf-regression gate and the roofline tooling consume);
print-only sections emit nothing. ``--list`` imports and resolves every
registered section without executing it, so a registration typo (module or
attribute rename) fails the build instead of silently dropping a JSON —
CI runs it as a smoke step.
"""
import argparse
import importlib
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp


def emit_json(name: str, payload, json_dir: str = "."):
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path}")


def _timeit(fn, n=5):
    fn()                                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def micro_rows():
    """name,us_per_call,derived microbenchmarks of the hot paths."""
    from repro.core.chunking import construct_chunks
    from repro.core.schedule_sim import chunks_to_microbatches, simulate_1f1b
    from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF
    from repro.kernels import ops

    rows = []
    s = LongTailSampler(PAPER_EVAL_CDF, seed=0, max_len=262144)
    lengths = dict(enumerate(s.sample_batch_lengths(256)))
    us = _timeit(lambda: construct_chunks(lengths, 8192))
    nch = len(construct_chunks(lengths, 8192))
    rows.append(("alg1_chunk_construction_b256", us, f"chunks={nch}"))

    chunks = construct_chunks(lengths, 8192)
    mbs = chunks_to_microbatches(chunks, k=4)
    us = _timeit(lambda: simulate_1f1b(mbs, 4, state_aware=True))
    rows.append(("state_aware_1f1b_sim", us, f"mbs={len(mbs)}"))

    B, T, P, Hq, Hkv, D = 1, 128, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, P + T, Hkv, D))
    v = jax.random.normal(ks[2], (B, P + T, Hkv, D))
    qp = (P + jnp.arange(T))[None]
    kp = jnp.arange(P + T)[None]
    ones_q = jnp.ones((B, T), jnp.int32)
    ones_k = jnp.ones((B, P + T), jnp.int32)
    f = lambda: ops.chunk_attention(q, k, v, qp, kp, ones_q, ones_k,
                                    block_q=64, block_k=64).block_until_ready()
    us = _timeit(f, n=3)
    rows.append(("pallas_chunk_attention_interpret", us,
                 f"T={T},P={P} (interpret mode — correctness proxy)"))
    return rows


def _run_micro(json_dir):
    print("name,us_per_call,derived")
    micro = micro_rows()
    for name, us, derived in micro:
        print(f"{name},{us:.0f},{derived}")
    return [{"name": n, "us_per_call": us, "derived": d}
            for n, us, d in micro]


def _run_pipeline_subprocess(json_dir):
    """The rotation executor needs >1 device; XLA_FLAGS must be set before
    jax initializes, so this section always runs as a subprocess (anchored
    to the repo root, extending — not clobbering — PYTHONPATH)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    pypath = os.pathsep.join(
        p for p in (os.path.join(root, "src"),
                    os.environ.get("PYTHONPATH")) if p)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.pipeline",
         "--json-dir", os.path.abspath(json_dir)],
        env=dict(os.environ, PYTHONPATH=pypath), cwd=root)
    if r.returncode:
        raise RuntimeError(f"benchmarks.pipeline failed ({r.returncode})")
    return None          # the subprocess emits BENCH_pipeline.json itself


# name, title, module (imported at run AND --list time), entry (a module
# attribute NAME — resolved at --list time so a rename fails the smoke step
# — or a local callable(json_dir)), entry kwargs, emits_json
SECTIONS = [
    ("length_distribution", "Tables 1-2: length distributions",
     "benchmarks.length_distribution", "run", {"n": 20_000}, False),
    ("bubble_ratio", "Figs 2/6/7: pipeline bubble ratios (analytic sim)",
     "benchmarks.bubble_ratio", "run", {}, False),
    ("memory_model", "Fig 1 + Table 5: memory model",
     "benchmarks.memory_model", "run", {}, False),
    ("end_to_end", "Fig 8 + Table 6: end-to-end iteration model",
     "benchmarks.end_to_end", "run", {}, False),
    ("dp_balance", "DP balance: LPT vs round-robin chunk-group assignment",
     "benchmarks.dp_balance", "run", {}, True),
    ("attention", "Attention backends: fwd+bwd walltime, compile counts, "
     "dense-vs-flash crossover",
     "benchmarks.attention", "run", {}, True),
    ("serving", "Serving engine: Poisson long-tail throughput + tail "
     "latency, mixed-tick vs prefill-stall",
     "benchmarks.serving", "run", {}, True),
    ("pipeline", "2D pipeline executor: bubble ratio + state bytes vs K "
     "(subprocess, 4 forced devices)",
     "benchmarks.pipeline", _run_pipeline_subprocess, {}, True),
    ("cp", "Context parallelism: ring-step counts, cp_threshold balance, "
     "per-device K/V bytes vs cp (deterministic planner/geometry math)",
     "benchmarks.context_parallel", "run", {}, True),
    ("planner", "Heterogeneous planner: solved per-wave cp vs best fixed "
     "(cp, C, K) config at world 8 (deterministic schedule_sim math)",
     "benchmarks.planner", "run", {}, True),
    ("micro", "Microbenchmarks", "benchmarks.run", _run_micro, {}, True),
    ("roofline", "Roofline (from dryrun_results.jsonl if present)",
     "benchmarks.roofline", "run", {}, False),
]


def _resolve_entry(name, module, entry):
    """-> callable. Imports the module either way; attribute-name entries
    must resolve to a callable or we raise (this is what --list checks)."""
    mod = importlib.import_module(module)
    if callable(entry):
        return entry
    fn = getattr(mod, entry, None)
    if not callable(fn):
        raise SystemExit(
            f"section {name!r}: {module}.{entry} is not a callable "
            "(renamed or removed? fix the SECTIONS registry)")
    return fn


def list_sections() -> None:
    """Import + resolve every section; print the registry. A typo in a
    module path or a renamed run() raises here and fails CI's smoke step."""
    print("name,emits_json,title")
    for name, title, module, entry, _kwargs, emits in SECTIONS:
        _resolve_entry(name, module, entry)
        print(f"{name},{emits},{title}")
    print(f"[bench] {len(SECTIONS)} sections registered")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".",
                    help="where BENCH_*.json payloads are written")
    ap.add_argument("--list", action="store_true",
                    help="import + list registered sections, run nothing")
    ap.add_argument("--only", nargs="*", default=None,
                    help="run only these sections")
    args = ap.parse_args(argv)

    if args.list:
        list_sections()
        return

    unknown = set(args.only or []) - {s[0] for s in SECTIONS}
    if unknown:
        raise SystemExit(f"unknown section(s) in --only: {sorted(unknown)}; "
                         "see --list")

    for name, title, module, entry, kwargs, emits in SECTIONS:
        if args.only and name not in args.only:
            continue
        print("=" * 70)
        print(f"## {title}")
        fn = _resolve_entry(name, module, entry)
        payload = fn(args.json_dir) if callable(entry) else fn(**kwargs)
        if emits and payload is not None:
            emit_json(name, payload, args.json_dir)


if __name__ == "__main__":
    main()
