"""Benchmark harness — one section per paper table/figure + microbenchmarks.

    PYTHONPATH=src python -m benchmarks.run [--json-dir DIR]

Sections that return a payload dict additionally emit it as
``BENCH_<section>.json`` (the machine-readable flow CI and the roofline
tooling consume); print-only sections emit nothing.
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp


def emit_json(name: str, payload, json_dir: str = "."):
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"[bench] wrote {path}")


def _timeit(fn, n=5):
    fn()                                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def micro_rows():
    """name,us_per_call,derived microbenchmarks of the hot paths."""
    from repro.core.chunking import construct_chunks
    from repro.core.schedule_sim import chunks_to_microbatches, simulate_1f1b
    from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF
    from repro.kernels import ops

    rows = []
    s = LongTailSampler(PAPER_EVAL_CDF, seed=0, max_len=262144)
    lengths = dict(enumerate(s.sample_batch_lengths(256)))
    us = _timeit(lambda: construct_chunks(lengths, 8192))
    nch = len(construct_chunks(lengths, 8192))
    rows.append(("alg1_chunk_construction_b256", us, f"chunks={nch}"))

    chunks = construct_chunks(lengths, 8192)
    mbs = chunks_to_microbatches(chunks, k=4)
    us = _timeit(lambda: simulate_1f1b(mbs, 4, state_aware=True))
    rows.append(("state_aware_1f1b_sim", us, f"mbs={len(mbs)}"))

    B, T, P, Hq, Hkv, D = 1, 128, 128, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, P + T, Hkv, D))
    v = jax.random.normal(ks[2], (B, P + T, Hkv, D))
    qp = (P + jnp.arange(T))[None]
    kp = jnp.arange(P + T)[None]
    ones_q = jnp.ones((B, T), jnp.int32)
    ones_k = jnp.ones((B, P + T), jnp.int32)
    f = lambda: ops.chunk_attention(q, k, v, qp, kp, ones_q, ones_k,
                                    block_q=64, block_k=64).block_until_ready()
    us = _timeit(f, n=3)
    rows.append(("pallas_chunk_attention_interpret", us,
                 f"T={T},P={P} (interpret mode — correctness proxy)"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".",
                    help="where BENCH_*.json payloads are written")
    args = ap.parse_args(argv)

    print("=" * 70)
    print("## Tables 1-2: length distributions")
    from benchmarks import length_distribution
    length_distribution.run(n=20_000)

    print("=" * 70)
    print("## Figs 2/6/7: pipeline bubble ratios")
    from benchmarks import bubble_ratio
    bubble_ratio.run()

    print("=" * 70)
    print("## Fig 1 + Table 5: memory model")
    from benchmarks import memory_model
    memory_model.run()

    print("=" * 70)
    print("## Fig 8 + Table 6: end-to-end iteration model")
    from benchmarks import end_to_end
    end_to_end.run()

    print("=" * 70)
    print("## DP balance: LPT vs round-robin chunk-group assignment")
    from benchmarks import dp_balance
    emit_json("dp_balance", dp_balance.run(), args.json_dir)

    print("=" * 70)
    print("## Attention backends: fwd+bwd walltime, compile counts, "
          "dense-vs-flash crossover")
    from benchmarks import attention
    emit_json("attention", attention.run(), args.json_dir)

    print("=" * 70)
    print("## Serving engine: Poisson long-tail throughput + tail latency, "
          "mixed-tick vs prefill-stall")
    from benchmarks import serving
    emit_json("serving", serving.run(), args.json_dir)

    print("=" * 70)
    print("## Microbenchmarks")
    print("name,us_per_call,derived")
    micro = micro_rows()
    for name, us, derived in micro:
        print(f"{name},{us:.0f},{derived}")
    emit_json("micro",
              [{"name": n, "us_per_call": us, "derived": d}
               for n, us, d in micro], args.json_dir)

    print("=" * 70)
    print("## Roofline (from dryrun_results.jsonl if present)")
    from benchmarks import roofline
    roofline.run()


if __name__ == "__main__":
    main()
