"""Paper Figs. 2, 6, 7 — pipeline bubble ratios (analytic simulator)."""
from repro.core.chunking import construct_chunks
from repro.core.schedule_sim import (chunks_to_microbatches,
                                     sequences_to_microbatches, simulate_1f1b)

LENGTHS = {0: 4, 1: 2, 2: 1, 3: 1}


def rows():
    out = []
    r = simulate_1f1b(sequences_to_microbatches([1, 1, 1, 1]), 4)
    out.append(("fig2_equal_len_1f1b", r.bubble_ratio, 0.428, r.makespan))
    base = simulate_1f1b(sequences_to_microbatches([4, 2, 1, 1]), 4)
    out.append(("fig2_variable_1f1b", base.bubble_ratio, 0.5714,
                base.makespan))

    chunks = construct_chunks(LENGTHS, 2)
    std = [c for c in chunks if not c.dependent]
    dep = [c for c in chunks if c.dependent]
    r1 = simulate_1f1b(chunks_to_microbatches(std + dep, k=0), 4,
                       state_aware=True)
    out.append(("fig6_state_aware_paperK1", r1.bubble_ratio, 0.541,
                r1.makespan))
    r2 = simulate_1f1b(chunks_to_microbatches(chunks, k=1), 4,
                       state_aware=True)
    out.append(("fig6_state_aware_paperK2", r2.bubble_ratio, 0.478,
                r2.makespan))
    out.append(("fig6_improvement_K1_vs_base",
                (base.makespan - r1.makespan) / base.makespan, 0.08, 0))
    out.append(("fig6_improvement_K2_vs_K1",
                (r1.makespan - r2.makespan) / r1.makespan, 0.12, 0))

    chunks7 = construct_chunks(LENGTHS, 4)
    r7 = simulate_1f1b(chunks_to_microbatches(chunks7, k=1), 4,
                       state_aware=True)
    out.append(("fig7_chunksize_too_large", r7.bubble_ratio, 0.60,
                r7.makespan))
    return out


def run():
    print("name,value,paper_value,makespan")
    for name, v, pv, m in rows():
        print(f"{name},{v:.4f},{pv},{m}")


if __name__ == "__main__":
    run()
