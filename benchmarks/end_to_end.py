"""Paper Fig. 8 + Table 6 — end-to-end iteration-time model.

No GPUs here, so iteration time is *modeled* with the same assumptions the
paper states (§3): execution time proportional to sequence length, backward
= 2x forward (3x under full recompute, 2.2x selective), plus one empirical
term the paper's Obs. 2 implies: a micro-step whose token count is below the
GPU saturation floor still pays the floor ("short sequences underutilize the
GPU"). Baseline = Megatron-style micro-batch-1 with Table-3 parallel configs;
ChunkFlow = Alg-1 chunks through the state-aware 1F1B simulator with Table-4
(ChunkSize, K).

Outputs the per-model speedups (paper: up to 4.53x) and the Table-6 U-shape.
"""
import numpy as np

from repro.core.chunking import construct_chunks
from repro.core.schedule_sim import (Microbatch, chunks_to_microbatches,
                                     simulate_1f1b)
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF

MICROSTEP_OVERHEAD = 2000        # token-equivalents of per-micro-step waste
# (smooth under-saturation model; calibrated so the Fig-8 max brackets the
#  paper's 4.53x AND Table 6 keeps its U-shape: OV=1600 -> 4.1x,
#  2000 -> ~4.8x, 2400 -> 5.4x)
ATTN_HORIZON = 32768             # quadratic-attention onset
GLOBAL_BATCH = 256

# paper Table 3: model -> {context: (TP, SP, PP, recompute)}
TABLE3 = {
    "7B":  {32: (4, 4, 1, "sel"), 256: (4, 4, 4, "full")},
    "14B": {32: (4, 4, 4, "sel"), 256: (4, 4, 4, "full")},
    "32B": {32: (4, 4, 4, "sel"), 256: (4, 4, 4, "full")},
    "72B": {32: (8, 8, 4, "sel"), 256: (8, 8, 4, "sel")},
}
# paper Table 4: ChunkFlow (ChunkSize, K)
TABLE4 = {
    "7B":  {32: (32768, 1), 256: (8192, 16)},
    "14B": {32: (8192, 8), 256: (8192, 8)},
    "32B": {32: (8192, 6), 256: (8192, 6)},
    "72B": {32: (8192, 16), 256: (8192, 16)},
}

BWD_FACTOR = {"sel": 2.2, "full": 3.0}


def seq_time(tokens, *, floor=True):
    """Relative compute time of a micro-step with `tokens` tokens: linear in
    tokens + fixed under-saturation overhead + quadratic attention term."""
    t = tokens + (MICROSTEP_OVERHEAD if floor else 0)
    return t * (1.0 + tokens / ATTN_HORIZON)


def baseline_iteration(lengths, pp, recompute):
    """Megatron: micro-batch 1 sequence; variable-length 1F1B."""
    mbs = [Microbatch(fwd=seq_time(l)) for l in
           sorted(lengths, reverse=True)]
    bf = BWD_FACTOR[recompute]
    # scale backwards by recompute factor: fold into fwd-equivalent units
    mbs = [Microbatch(fwd=m.fwd * (1 + bf) / 3.0) for m in mbs]
    if pp == 1:
        return sum(3.0 * m.fwd for m in mbs)
    return simulate_1f1b(mbs, pp).makespan


def chunkflow_iteration(lengths, pp, chunk_size, k):
    chunks = construct_chunks(dict(enumerate(lengths)), chunk_size)
    mbs = chunks_to_microbatches(chunks, k=k)
    mbs = [Microbatch(fwd=seq_time(m.fwd) * (1 + 2.2) / 3.0, group=m.group,
                      index_in_group=m.index_in_group,
                      group_size=m.group_size, recompute=m.recompute)
           for m in mbs]
    if pp == 1:
        total = 0.0
        for m in mbs:
            total += 3.0 * m.fwd + (m.fwd if m.recompute else 0.0)
        return total
    return simulate_1f1b(mbs, pp, state_aware=True).makespan


def fig8_rows(seed=0):
    rows = []
    for ctx in (32, 256):
        sampler = LongTailSampler(PAPER_EVAL_CDF, min_len=32, seed=seed,
                                  max_len=ctx * 1024)
        lengths = sampler.sample_batch_lengths(GLOBAL_BATCH)
        for model in ("7B", "14B", "32B", "72B"):
            tp, sp, pp, rec = TABLE3[model][ctx]
            cs, k = TABLE4[model][ctx]
            # per-DP-rank share (same #GPUs both systems -> same DP)
            base = baseline_iteration(lengths, pp, rec)
            cf = chunkflow_iteration(lengths, pp, cs, k)
            rows.append((f"fig8_{model}_{ctx}K", base / cf))
    return rows


def table6_rows(seed=0):
    sampler = LongTailSampler(PAPER_EVAL_CDF, min_len=32, seed=seed,
                              max_len=256 * 1024)
    lengths = sampler.sample_batch_lengths(GLOBAL_BATCH)
    rows = []
    for cs, k in ((2048, 16), (8192, 4), (32768, 1)):
        t = chunkflow_iteration(lengths, 4, cs, k)
        rows.append((f"table6_cs{cs//1024}K_k{k}", t))
    return rows


def run():
    print("name,value")
    speedups = fig8_rows()
    for name, v in speedups:
        print(f"{name},{v:.2f}x")
    mx = max(v for _, v in speedups)
    print(f"fig8_max_speedup,{mx:.2f}x  (paper: up to 4.53x)")
    assert 2.0 <= mx <= 8.0, "modeled speedup should bracket the paper's"
    # long contexts gain at least as much as short (paper Fig. 8 trend)
    assert (max(v for n, v in speedups if "256K" in n)
            >= max(v for n, v in speedups if "32K" in n))
    t6 = table6_rows()
    best = min(v for _, v in t6)
    for name, v in t6:
        print(f"{name},{v/best:.3f} (rel to best; paper rel: "
              f"1.254/1.000/1.217 — our (32K,1) bubble penalty is stronger "
              f"than the paper's)")
    # U-shape assertion: the middle config wins (paper Table 6)
    assert t6[1][1] <= t6[0][1] and t6[1][1] <= t6[2][1]


if __name__ == "__main__":
    run()
