"""Paper Fig. 1 + Table 5 — memory footprint model.

ChunkFlow's peak memory is linear:  peak = W + A*ChunkSize*K + V*context
  W  — weights + grads + optimizer shard (per GPU)
  A  — live activation bytes per chunk token (selective recompute, TP4/SP)
  V  — stored K/V state bytes per context token (the paper keeps all K/V)

The three coefficients are IDENTIFIED FROM the paper's Table 5 itself
(6 measurements, 3 unknowns, overdetermined):
    A: (47.5-41.6)/2048 = (59.3-47.5)/4096 = 2.88 MB/token  (consistent!)
    V: (45.6-41.6)/224K ~= (63.8-59.3)/224K ~= 18 KB/token
    W: 41.6 - 2048*A - 32K*V = 35.1 GB
The model then PREDICTS all six cells within ~5% — i.e. the paper's central
memory claim (peak ~= f(ChunkSize), context adds only the small K/V term) is
internally consistent, and our scheduler's accounting
(tests/test_chunked_equivalence.py: <=K live residual sets; statestore holds
all K/V) matches that structure exactly.

Fig. 1: micro-step memory across a sampled long-tail stream under the
baseline (activations ~ sequence length) vs ChunkFlow (constant).
"""
import numpy as np

from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF

W_GB = 35.1
A_GB_PER_TOKEN = 2.88e-3
V_GB_PER_TOKEN = 17.9e-6

PAPER_TABLE5 = {  # (context, chunk_size) -> GiB
    (32_768, 2048): 41.6, (262_144, 2048): 45.6,
    (32_768, 4096): 47.5, (262_144, 4096): 50.8,
    (32_768, 8192): 59.3, (262_144, 8192): 63.8,
}


def chunkflow_peak_gb(context_len, chunk_size, k=1):
    return (W_GB + k * chunk_size * A_GB_PER_TOKEN
            + context_len * V_GB_PER_TOKEN)


def baseline_peak_gb(max_seq):
    return W_GB + max_seq * A_GB_PER_TOKEN


def run():
    print("table5: context,chunk_size,model_gb,paper_gb,err%")
    worst = 0.0
    for (ctx, cs), paper in sorted(PAPER_TABLE5.items(),
                                   key=lambda kv: (kv[0][1], kv[0][0])):
        m = chunkflow_peak_gb(ctx, cs)
        err = abs(m - paper) / paper * 100
        worst = max(worst, err)
        print(f"table5,{ctx},{cs},{m:.1f},{paper},{err:.1f}%")
    assert worst < 6.0, f"Table 5 model error {worst:.1f}%"
    # the paper's structural claims
    for cs in (2048, 4096, 8192):
        assert (chunkflow_peak_gb(262_144, cs)
                - chunkflow_peak_gb(32_768, cs)) < 6.0   # K/V term only
    assert (chunkflow_peak_gb(32_768, 8192)
            > chunkflow_peak_gb(262_144, 2048))          # ChunkSize dominates

    print("fig1: micro-step memory across 1000 sampled micro-steps")
    s = LongTailSampler(PAPER_EVAL_CDF, seed=1, max_len=32 * 1024)
    lens = [s.sample_length() for _ in range(1000)]
    base = [baseline_peak_gb(l) for l in lens]
    peak, p977 = max(base), float(np.percentile(base, 97.7))
    print(f"fig1,baseline,peak_gb,{peak:.1f} (paper: 75)")
    print(f"fig1,baseline,p97.7_gb,{p977:.1f} (paper: 97.7% of steps <45)")
    cf = chunkflow_peak_gb(32 * 1024, 8192)
    print(f"fig1,chunkflow,const_gb,{cf:.1f}")
    assert p977 < 0.75 * peak            # the underutilization the paper shows


if __name__ == "__main__":
    run()
