"""Serving engine benchmark: throughput + tail latency under Poisson
arrivals with the paper's long-tail prompt-length distribution.

Two engine modes on the identical request trace:
  * mixed          — prefill chunks ride along with decode every tick
                     (continuous batching, the engine default);
  * prefill_stall  — a tick is either prefill or decode (``mixed=False``),
                     the static-batching baseline where a long admitted
                     prompt stalls every running decode.

Emitted as BENCH_serving.json by benchmarks/run.py (and a CI artifact):
throughput (tok/s), p50/p99 TTFT and end-to-end latency, engine counters
(preemptions, padded prefill tokens, peak pages).

    PYTHONPATH=src python -m benchmarks.serving [--json-dir DIR]
"""
import argparse
import dataclasses
import time

import jax

from repro.configs.base import ModelConfig
from repro.models import api
from repro.serving import Engine, EngineConfig, poisson_requests
from repro.serving.frontend import latency_percentiles


def bench_cfg():
    return ModelConfig(name="bench-serve", family="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                       d_ff=128, vocab_size=256, dtype="float32",
                       rope_theta=10_000.0)


def run(n_requests: int = 24, rate: float = 40.0, gen: int = 8,
        seed: int = 0) -> dict:
    cfg = bench_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=16, pages_total=64, max_running=4,
                        prefill_chunk=32, prefill_slots=1,
                        max_pages_per_req=16)
    max_prompt = ecfg.max_model_len - gen - ecfg.prefill_chunk

    payload = {"config": {"n_requests": n_requests, "poisson_rate": rate,
                          "gen_tokens": gen, "length_dist": "paper_eval",
                          "max_prompt": max_prompt,
                          **dataclasses.asdict(ecfg)}}
    print("mode,tok_s,ttft_p50,ttft_p99,e2e_p50,e2e_p99,ticks,preemptions")
    for mode, mixed in [("mixed", True), ("prefill_stall", False)]:
        engine = Engine(cfg, params, dataclasses.replace(ecfg, mixed=mixed))
        engine.warmup()                     # compile off the measured path
        reqs = poisson_requests(n_requests, rate, vocab_size=cfg.vocab_size,
                                dist="paper_eval", seed=seed,
                                max_new_tokens=gen, max_prompt=max_prompt)
        t0 = time.perf_counter()
        results = engine.run(reqs, clock="wall")
        dt = time.perf_counter() - t0
        lat = latency_percentiles(results)
        toks = sum(len(r.tokens) for r in results)
        payload[mode] = {
            "wall_s": dt,
            "throughput_tok_s": toks / dt,
            "ttft": lat["ttft"],
            "e2e": lat["e2e"],
            **engine.summary(),
        }
        m = payload[mode]
        print(f"{mode},{m['throughput_tok_s']:.1f},"
              f"{m['ttft']['p50']:.3f},{m['ttft']['p99']:.3f},"
              f"{m['e2e']['p50']:.3f},{m['e2e']['p99']:.3f},"
              f"{m['ticks']},{m['n_preemptions']}")

    payload["mixed_speedup_e2e_p99"] = (
        payload["prefill_stall"]["e2e"]["p99"] / payload["mixed"]["e2e"]["p99"]
        if payload["mixed"]["e2e"]["p99"] else None)
    print(f"mixed-tick e2e p99 speedup over prefill-stall: "
          f"{payload['mixed_speedup_e2e_p99']:.2f}x")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".")
    ap.add_argument("--n", type=int, default=24)
    args = ap.parse_args(argv)
    from benchmarks.run import emit_json
    emit_json("serving", run(n_requests=args.n), args.json_dir)


if __name__ == "__main__":
    main()
