"""DP-balance benchmark — LPT vs round-robin chunk-group assignment.

Samples global batches from the paper's long-tail CDF (Table 2), runs
Algorithm 1 chunk construction, builds token-work units, and plans them onto
DP ranks under both policies. Reports the metric the paper's load-imbalance
argument is about: **max-rank token work** (every other rank waits for it at
the gradient all-reduce), plus the wave-padding waste the SPMD executor
actually pays (core/chunked_step._run_batch_dp).
"""
import numpy as np

from repro.core import dp_balance
from repro.core.chunking import construct_chunks, group_chunks
from repro.data.synthetic import LongTailSampler, PAPER_EVAL_CDF

# ChunkSize chosen so a 256-sequence paper-CDF batch yields a realistic unit
# mix (~32 units: packed bins + the occasional multi-chunk tail group); at
# 8192 nearly everything folds into a handful of equal bins and there is
# nothing left to balance.
CHUNK_SIZE = 2048
GLOBAL_BATCH = 256
N_TRIALS = 5


def rows(seed: int = 0):
    out = []
    for world_size in (2, 4, 8, 16):
        agg = {p: {"max_rank_work": [], "imbalance": [], "padded": []}
               for p in ("round_robin", "lpt")}
        for trial in range(N_TRIALS):
            s = LongTailSampler(PAPER_EVAL_CDF, seed=seed * 1000 + trial,
                                max_len=262_144)
            lengths = dict(enumerate(s.sample_batch_lengths(GLOBAL_BATCH)))
            chunks = construct_chunks(lengths, CHUNK_SIZE)
            groups, standalone = group_chunks(chunks)
            units = dp_balance.units_from_chunks(groups, standalone, k=2)
            cmp = dp_balance.compare_policies(units, world_size)
            for pol, m in cmp.items():
                agg[pol]["max_rank_work"].append(m["max_rank_work"])
                agg[pol]["imbalance"].append(m["imbalance"])
                agg[pol]["padded"].append(m["padded_slot_fraction"])
        row = {"world_size": world_size}
        for pol in ("round_robin", "lpt"):
            row[pol] = {k: float(np.mean(v)) for k, v in agg[pol].items()}
        row["max_work_reduction"] = 1.0 - (
            row["lpt"]["max_rank_work"] / row["round_robin"]["max_rank_work"])
        out.append(row)
    return out


def run(seed: int = 0):
    """Print the comparison table; return the BENCH payload dict."""
    data = rows(seed)
    print(f"paper-CDF batch={GLOBAL_BATCH}, ChunkSize={CHUNK_SIZE}, "
          f"{N_TRIALS} trials")
    print("world,rr_max_work,lpt_max_work,reduction,"
          "rr_imbalance,lpt_imbalance,rr_padded,lpt_padded")
    for r in data:
        rr, lpt = r["round_robin"], r["lpt"]
        print(f"{r['world_size']},{rr['max_rank_work']:.0f},"
              f"{lpt['max_rank_work']:.0f},{r['max_work_reduction']:.3f},"
              f"{rr['imbalance']:.3f},{lpt['imbalance']:.3f},"
              f"{rr['padded']:.3f},{lpt['padded']:.3f}")
    return {
        "chunk_size": CHUNK_SIZE,
        "global_batch": GLOBAL_BATCH,
        "n_trials": N_TRIALS,
        "rows": data,
    }


if __name__ == "__main__":
    run()
